"""Pallas TPU kernel: weighted centroid accumulation (segment-sum).

The Lloyd update is a segment-sum of points by label.  Scatter-adds are
VPU-serial on TPU; the MXU-native formulation is a one-hot matmul:

    sums   = onehot(labels)^T @ X        (k x bn) @ (bn x d)
    counts = sum(onehot(labels), axis=0)

Grid streams n-tiles through VMEM; the (k, d) output block is revisited every
step and accumulated in place (k is small for k-means, so the whole output
fits VMEM).  Padded points carry weight 0 and padded labels point at row k
(sliced off by the wrapper), so no masking branch is needed in the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(x_ref, lab_ref, w_ref, sums_ref, counts_ref, *, k_pad: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                     # (bn, d)
    lab = lab_ref[...]                                     # (bn,)
    w = w_ref[...].astype(jnp.float32)                     # (bn,)

    onehot = (lab[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (lab.shape[0], k_pad), 1)).astype(jnp.float32)
    onehot = onehot * w[:, None]

    local_sums = jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    local_counts = jnp.sum(onehot, axis=0)[None, :]        # (1, k_pad)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = local_sums
        counts_ref[...] = local_counts

    @pl.when(i > 0)
    def _accumulate():
        sums_ref[...] += local_sums
        counts_ref[...] += local_counts


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def centroid_update_pallas(points: jnp.ndarray,
                           labels: jnp.ndarray,
                           weights: jnp.ndarray,
                           k: int,
                           *,
                           block_n: int = 512,
                           interpret: bool = False):
    """(n,d),(n,),(n,) -> sums (k,d) f32, counts (k,) f32."""
    n, d = points.shape
    bn = min(block_n, max(8, n))
    n_pad = -(-n // bn) * bn
    d_pad = max(-(-d // 128) * 128, 128)
    k_pad = max(-(-(k + 1) // 8) * 8, 8)    # +1 trash row for padded points

    x = jnp.zeros((n_pad, d_pad), points.dtype).at[:n, :d].set(points)
    lab = jnp.full((n_pad,), k, jnp.int32).at[:n].set(labels.astype(jnp.int32))
    w = jnp.zeros((n_pad,), jnp.float32).at[:n].set(weights.astype(jnp.float32))

    grid = (n_pad // bn,)
    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel, k_pad=k_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k_pad, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, k_pad), jnp.float32),
        ],
        interpret=interpret,
    )(x, lab, w)

    return sums[:k, :d], counts[0, :k]
