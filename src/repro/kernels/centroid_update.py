"""Pallas TPU kernel: weighted centroid accumulation (segment-sum).

The Lloyd update is a segment-sum of points by label.  Scatter-adds are
VPU-serial on TPU; the MXU-native formulation is a one-hot matmul:

    sums   = onehot(labels)^T @ X        (k x bn) @ (bn x d)
    counts = sum(onehot(labels), axis=0)

Grid streams n-tiles through VMEM; the (k, d) output block is revisited every
step and accumulated in place (k is small for k-means, so the whole output
fits VMEM).  Padded points carry weight 0 and padded labels point at row k
(sliced off by the wrapper), so no masking branch is needed in the kernel.

Block geometry arrives as a :class:`~repro.kernels.specs.KernelSpec`
(``specs.UPDATE_DEFAULT_SPEC`` when unset — this kernel's default tile is
taller, ``block_n=512``, because it has no k-blocking to feed); the loose
``block_n`` int remains as a deprecated shim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import KernelSpec


def _update_kernel(x_ref, lab_ref, w_ref, sums_ref, counts_ref, *,
                   k_pad: int, acc):
    i = pl.program_id(0)
    x = x_ref[...].astype(acc)                             # (bn, d)
    lab = lab_ref[...]                                     # (bn,)
    w = w_ref[...].astype(acc)                             # (bn,)

    onehot = (lab[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (lab.shape[0], k_pad), 1)).astype(acc)
    onehot = onehot * w[:, None]

    local_sums = jnp.dot(onehot.T, x,
                         preferred_element_type=acc).astype(jnp.float32)
    local_counts = jnp.sum(onehot.astype(jnp.float32), axis=0)[None, :]

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = local_sums
        counts_ref[...] = local_counts

    @pl.when(i > 0)
    def _accumulate():
        sums_ref[...] += local_sums
        counts_ref[...] += local_counts


@functools.partial(jax.jit, static_argnames=("k", "spec"))
def _centroid_update_pallas(points: jnp.ndarray,
                            labels: jnp.ndarray,
                            weights: jnp.ndarray,
                            k: int,
                            *,
                            spec: KernelSpec):
    n, d = points.shape
    bn, n_pad, k_pad, d_pad = spec.update_tile_shapes(n, d, k)

    x = jnp.zeros((n_pad, d_pad), points.dtype).at[:n, :d].set(points)
    lab = jnp.full((n_pad,), k, jnp.int32).at[:n].set(labels.astype(jnp.int32))
    w = jnp.zeros((n_pad,), jnp.float32).at[:n].set(weights.astype(jnp.float32))

    grid = (n_pad // bn,)
    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel, k_pad=k_pad,
                          acc=jnp.dtype(spec.acc_dtype)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k_pad, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, k_pad), jnp.float32),
        ],
        interpret=bool(spec.interpret),
    )(x, lab, w)

    return sums[:k, :d], counts[0, :k]


def centroid_update_pallas(points: jnp.ndarray,
                           labels: jnp.ndarray,
                           weights: jnp.ndarray,
                           k: int,
                           *,
                           spec: KernelSpec | None = None,
                           block_n: int | None = None,
                           interpret: bool | None = None):
    """(n,d),(n,),(n,) -> sums (k,d) f32, counts (k,) f32."""
    spec = specs.coerce(spec, block_n=block_n, interpret=interpret,
                        default=specs.UPDATE_DEFAULT_SPEC)
    return _centroid_update_pallas(
        points, labels, weights, k,
        spec=spec.with_interpret(bool(spec.interpret)))
