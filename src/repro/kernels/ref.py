"""Pure-jnp oracles for the Pallas kernels.  Ground truth for all tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def assign_ref(points: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment: (n,d),(k,d) -> labels (n,) i32, min sq
    distances (n,) f32.  Ties break to the lowest index (argmin semantics)."""
    x2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    c = centroids.astype(jnp.float32)
    c2 = jnp.sum(c ** 2, axis=-1)[None, :]
    d2 = x2 - 2.0 * (points.astype(jnp.float32) @ c.T) + c2
    d2 = jnp.maximum(d2, 0.0)
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    mind = jnp.take_along_axis(d2, labels[:, None], axis=-1)[:, 0]
    return labels, mind


def centroid_update_ref(points: jnp.ndarray, labels: jnp.ndarray,
                        weights: jnp.ndarray, k: int):
    """Weighted per-cluster sums and counts: -> sums (k,d) f32, counts (k,) f32."""
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32) * weights[:, None].astype(jnp.float32)
    sums = onehot.T @ points.astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def lloyd_step_ref(points: jnp.ndarray, centroids: jnp.ndarray,
                   weights: jnp.ndarray | None = None):
    """Oracle for the fused kernel: one Lloyd pass over the data ->
    sums (k,d) f32, counts (k,) f32, sse () f32.  Composes the two
    single-phase oracles, so the fused kernel is tested against exactly the
    semantics the two-kernel path implements."""
    k = centroids.shape[0]
    w = (jnp.ones(points.shape[0], jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    labels, mind = assign_ref(points, centroids)
    sums, counts = centroid_update_ref(points, labels, w, k)
    sse = jnp.sum(w * mind)
    return sums, counts, sse
