"""Pure-jnp oracles for the Pallas kernels.  Ground truth for all tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def divide_or_keep(sums: jnp.ndarray, counts: jnp.ndarray,
                   old_centroids: jnp.ndarray) -> jnp.ndarray:
    """Keep-old-centroid division policy: ``sums / counts`` where a cluster
    captured points, the previous centroid where it is empty.  The single
    definition every solver loop and kernel uses (pure jnp, traces on-chip);
    callers pick the dtypes of ``sums``/``old_centroids``."""
    return jnp.where(counts[:, None] > 0.0,
                     sums / jnp.maximum(counts[:, None], 1.0),
                     old_centroids)


def reseed_farthest(points: jnp.ndarray, score: jnp.ndarray,
                    empty: jnp.ndarray, kk: int):
    """Farthest-point re-selection core: which centroid rows to replace, and
    with which points.  ONE definition shared by the host-side oracle
    (``engine.reseed_empty_clusters``) and the in-kernel reseed of the
    resident / batched-resident solvers, so their bit-for-bit parity contract
    rests on shared code — exactly like ``divide_or_keep``.

    Semantics (Bahmani et al.-style D^2 extremes): the ``e``-th empty cluster
    (in index order) takes the ``e``-th farthest valid point — equal scores
    break to the lowest point index, matching ``jax.lax.top_k``'s stable
    order.  A slot is consumed per empty cluster whether or not it can be
    served; an empty cluster keeps its old centroid when the candidate pool
    is exhausted (``e >= kk``) or the next score is not finite (all valid
    rows already consumed into ``-inf``).

    Args:
      points: (n, d) candidate rows (any dtype — picks are exact copies:
        the one-hot select multiplies by 0/1 and sums zeros, both exact).
      score: (n,) f32 re-selection score, ``-inf`` for invalid rows.
      empty: (k,) bool — centroid rows to re-seed (padded rows ``False``).
      kk: static candidate budget, ``min(k_actual, n_actual)``.

    Returns ``(take (k,) bool, picks (k, d))``: replace row ``j`` with
    ``picks[j]`` where ``take[j]``.  Pure jnp built from masked max/min
    reductions and 2-D iotas only, so it traces on-chip (Pallas/Mosaic) as
    well as on host.
    """
    n, d = points.shape
    k = empty.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)[:, 0]
    clu = jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0)[:, 0]

    def body(j, carry):
        take, picks, live, e = carry
        at_j = clu == j

        def grab(args):
            take, picks, live, e = args
            best = jnp.max(live)
            # first-index tie-break, same stable order as lax.top_k
            first = jnp.min(jnp.where(live == best, row, n))
            ok = jnp.logical_and(e < kk, jnp.isfinite(best))
            sel = jnp.logical_and(row == first, ok)             # (n,)
            pick = jnp.sum(points * sel[:, None].astype(points.dtype),
                           axis=0)                              # exact copy
            take = jnp.logical_or(take, jnp.logical_and(at_j, ok))
            picks = jnp.where(jnp.logical_and(at_j, ok)[:, None],
                              pick[None, :], picks)
            live = jnp.where(sel, -jnp.inf, live)
            return take, picks, live, e + 1

        is_empty = jnp.any(jnp.logical_and(empty, at_j))
        return jax.lax.cond(is_empty, grab, lambda a: a,
                            (take, picks, live, e))

    take, picks, _, _ = jax.lax.fori_loop(
        0, k, body,
        (jnp.zeros((k,), bool), jnp.zeros((k, d), points.dtype),
         score.astype(jnp.float32), jnp.int32(0)))
    return take, picks


def minibatch_merge(centroids: jnp.ndarray, counts: jnp.ndarray,
                    sums: jnp.ndarray, bcounts: jnp.ndarray):
    """Fold one batch's (sums, bcounts) into running (centroids, counts).

    This closed form IS Sculley's sequential mini-batch k-means update
    ("Web-Scale K-Means Clustering", PAPERS.md): walking the batch point by
    point with per-center count-decayed learning rates ``eta = w / count``
    (assignments fixed at batch start) telescopes to exactly the weighted
    running mean

        new_c[j] = (counts[j] * c[j] + sums[j]) / (counts[j] + bcounts[j])

    — each step computes the running mean of everything seen so far, so the
    batch collapses to one merge.  Centers the batch never touched keep
    their coordinates bit-for-bit (the ``where``, not a ``c*n/n`` round
    trip).  ONE definition shared by the jnp oracle below and the fused
    engine path (``engine.FusedEngine.update_minibatch``), mirroring
    ``divide_or_keep``.

    Returns ``(new_centroids (k,d) f32, new_counts (k,) f32)``.
    """
    c = centroids.astype(jnp.float32)
    counts = counts.astype(jnp.float32)
    new_counts = counts + bcounts
    new_c = jnp.where(bcounts[:, None] > 0.0,
                      (counts[:, None] * c + sums)
                      / jnp.maximum(new_counts[:, None], 1.0),
                      c)
    return new_c, new_counts


def minibatch_update_ref(points: jnp.ndarray, centroids: jnp.ndarray,
                         counts: jnp.ndarray,
                         weights: jnp.ndarray | None = None):
    """Oracle for one mini-batch refresh: (n,d),(k,d),(k,)[,(n,)] ->
    (new_centroids (k,d) f32, new_counts (k,) f32, sse () f32).

    One nearest-centroid pass over the batch (assignments fixed at batch
    start, per Sculley), a weighted segment-sum, then the
    :func:`minibatch_merge` closed form.  ``sse`` is the batch's weighted
    SSE against the *incoming* centroids — the score of what was being
    served when the batch arrived, which is what a drift monitor wants."""
    k = centroids.shape[0]
    w = (jnp.ones(points.shape[0], jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    labels, mind = assign_ref(points, centroids)
    sums, bcounts = centroid_update_ref(points, labels, w, k)
    new_c, new_counts = minibatch_merge(centroids, counts, sums, bcounts)
    return new_c, new_counts, jnp.sum(w * mind)


def assign_ref(points: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment: (n,d),(k,d) -> labels (n,) i32, min sq
    distances (n,) f32.  Ties break to the lowest index (argmin semantics)."""
    x2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    c = centroids.astype(jnp.float32)
    c2 = jnp.sum(c ** 2, axis=-1)[None, :]
    d2 = x2 - 2.0 * (points.astype(jnp.float32) @ c.T) + c2
    d2 = jnp.maximum(d2, 0.0)
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    mind = jnp.take_along_axis(d2, labels[:, None], axis=-1)[:, 0]
    return labels, mind


def centroid_update_ref(points: jnp.ndarray, labels: jnp.ndarray,
                        weights: jnp.ndarray, k: int):
    """Weighted per-cluster sums and counts: -> sums (k,d) f32, counts (k,) f32."""
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32) * weights[:, None].astype(jnp.float32)
    sums = onehot.T @ points.astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def init_sweep_ref(points: jnp.ndarray, cands: jnp.ndarray,
                   old_mind: jnp.ndarray, uniforms: jnp.ndarray,
                   psi_prev, *, ell: float,
                   cand_valid: jnp.ndarray | None = None,
                   weights: jnp.ndarray | None = None,
                   block_rows: int | None = None):
    """Oracle for the fused k-means|| round sweep (``kernels/init.py``):
    (n,d),(c,d),(n,),(n,),() -> (new_mind (n,) f32, sampled (n,) bool,
    psi () f32).

    Same expressions in the same order as the kernel — ``||c||^2 - 2 x.c``
    with ``||x||^2`` added back post-min, invalid candidates masked to +inf
    norms, the Bernoulli draw ``u * psi_prev < ell * new_mind`` gated on
    positive weight and positive previous potential — so ``new_mind`` and
    ``sampled`` are bitwise against the kernel.  ``block_rows`` (the kernel's
    ``block_n``) makes the potential reduction bitwise too, by accumulating
    per-block partial sums in the kernel's sequential grid order; ``None``
    uses a flat ``jnp.sum`` (same value up to reduction order — the driver's
    fast path).
    """
    xf = points.astype(jnp.float32)
    cf = cands.astype(jnp.float32)
    # norms from the UNPADDED candidates (the kernel wrapper streams them in
    # precomputed exactly so)...
    norms = jnp.sum(cf ** 2, axis=-1)
    if cand_valid is not None:
        norms = jnp.where(cand_valid, norms, jnp.inf)
    # ...but the dot contractions padded like the kernel's tiles: d
    # zero-padded to the 128-lane boundary and the candidate axis to the
    # 8-column sublane minimum (+inf norms).  Both pads are value-neutral
    # yet change XLA's lowering — a wider contraction re-trees the per-
    # element reduction, and a 1-column dot lowers as a mat-vec with its
    # own accumulation order — so matching them is what keeps parity
    # bitwise at d > 128 and c < 8.
    d = points.shape[1]
    c = cands.shape[0]
    d_pad = max(-(-d // 128) * 128, 128)
    c_pad = max(c, 8)
    xp = jnp.zeros((points.shape[0], d_pad), jnp.float32).at[:, :d].set(xf)
    cp = jnp.zeros((c_pad, d_pad), jnp.float32).at[:c, :d].set(cf)
    np_ = jnp.full((c_pad,), jnp.inf, jnp.float32).at[:c].set(norms)
    best = jnp.min(np_[None, :] - 2.0 * (xp @ cp.T), axis=1)
    x2 = jnp.sum(xp * xp, axis=1)
    cand_min = jnp.maximum(best + x2, 0.0)
    mind = jnp.minimum(old_mind.astype(jnp.float32), cand_min)
    n = points.shape[0]
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    u = uniforms.astype(jnp.float32)
    pp = jnp.asarray(psi_prev, jnp.float32)
    take = jnp.logical_and(u * pp < ell * mind,
                           jnp.logical_and(w > 0.0, pp > 0.0))
    contrib = w * mind
    if block_rows is None:
        psi = jnp.sum(contrib)
    else:
        bb = max(1, min(int(block_rows), n))
        n_pad = -(-n // bb) * bb
        padded = jnp.zeros((n_pad,), jnp.float32).at[:n].set(contrib)
        psi = jnp.float32(0.0)
        for b in range(n_pad // bb):      # static grid: kernel's += order
            psi = psi + jnp.sum(padded[b * bb:(b + 1) * bb])
    return mind, take, psi


def lloyd_step_ref(points: jnp.ndarray, centroids: jnp.ndarray,
                   weights: jnp.ndarray | None = None):
    """Oracle for the fused kernel: one Lloyd pass over the data ->
    sums (k,d) f32, counts (k,) f32, sse () f32.  Composes the two
    single-phase oracles, so the fused kernel is tested against exactly the
    semantics the two-kernel path implements."""
    k = centroids.shape[0]
    w = (jnp.ones(points.shape[0], jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    labels, mind = assign_ref(points, centroids)
    sums, counts = centroid_update_ref(points, labels, w, k)
    sse = jnp.sum(w * mind)
    return sums, counts, sse


# --------------------------------------------------------------- pruning --
#
# Hamerly-style triangle-inequality bounds (see "Improving The Performance
# Of The K-means Algorithm", PAPERS.md), at point-BLOCK granularity: a block
# whose worst-case margin (second-best distance minus best distance, min'd
# over the block) exceeds twice the centroid drift accumulated since the
# block was last scored provably keeps every assignment, so its score pass
# can be skipped.  The three helpers below are pure jnp (2-D iota only), so
# they trace on-chip — the resident/batched kernels and the jnp oracle share
# ONE definition of the skip condition, exactly like ``divide_or_keep``.


def bound_second_best(scores: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Min score over the non-assigned centroids: (..., k), (...) -> (...).

    With k == 1 (or every other column masked to +inf) this is +inf — the
    gap is unbounded and the block is skippable forever, which is correct:
    a single centroid can never steal an assignment.
    """
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, scores.ndim - 1)
    masked = jnp.where(col == labels[..., None], jnp.inf, scores)
    return jnp.min(masked, axis=-1)


def bound_gap(best_sq: jnp.ndarray, second_sq: jnp.ndarray,
              valid: jnp.ndarray) -> jnp.ndarray:
    """Per-point reassignment margin in DISTANCE units: d2 - d1 from the
    squared best/second-best distances, +inf for invalid (padding) rows so
    they never constrain a block's margin."""
    gap = (jnp.sqrt(jnp.maximum(second_sq, 0.0))
           - jnp.sqrt(jnp.maximum(best_sq, 0.0)))
    return jnp.where(valid, gap, jnp.inf)


def bounds_may_skip(margin: jnp.ndarray, drift: jnp.ndarray) -> jnp.ndarray:
    """The triangle-inequality skip condition.  ``margin`` is the block's
    stored worst-case gap (d2 - d1 at the last scored iteration); ``drift``
    the total max per-centroid movement accumulated since.  Every point's
    best distance grew by at most ``drift`` and its second-best shrank by at
    most ``drift``, so ``margin > 2 * drift`` proves no assignment in the
    block can change.  Strict inequality: a fresh block carries ``-inf``
    margin and never skips its first pass."""
    return margin > 2.0 * drift


def lloyd_solve_bounds_ref(points: jnp.ndarray, centroids: jnp.ndarray,
                           weights: jnp.ndarray | None = None,
                           *, max_iters: int = 300, tol: float = 1e-6,
                           block_rows: int = 64):
    """Bound-pruned solve oracle: ``lloyd_solve_ref`` with the block-skip
    logic of the pruned kernels -> (centroids, sse, iters, converged,
    skips (max_iters, 2) i32 — [blocks skipped, blocks total] per iteration).

    The oracle computes the full score matrix every iteration (it is ground
    truth, not a fast path) but SELECTS the cached assignment for blocks the
    bound declares skippable — so an unsound bound (a "skipped" block that
    would in fact reassign) diverges from :func:`lloyd_solve_ref` and the
    bit-for-bit parity assertion catches it.  The compute path (assignment,
    segment-sum, stop criterion, final statistics) is structurally identical
    to ``lloyd_solve_ref`` — no padding, same expressions — which is why
    parity is exact, not approximate.
    """
    from repro.core.metrics import centroid_shift
    n, d = points.shape
    k = centroids.shape[0]
    bb = max(1, min(int(block_rows), n))
    n_pad = -(-n // bb) * bb
    nb = n_pad // bb
    iters_rows = max(int(max_iters), 1)
    w = (jnp.ones(n, jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    x = points.astype(jnp.float32)

    def full_assign(c):
        # assign_ref's expression inlined: the bound pass needs the full d2
        # matrix (for second-best distances), which assign_ref does not
        # expose.  Same ops in the same order keep labels/mind bitwise.
        x2 = jnp.sum(x ** 2, axis=-1, keepdims=True)
        c2 = jnp.sum(c ** 2, axis=-1)[None, :]
        d2 = jnp.maximum(x2 - 2.0 * (x @ c.T) + c2, 0.0)
        labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
        mind = jnp.take_along_axis(d2, labels[:, None], axis=-1)[:, 0]
        return d2, labels, mind

    def block_min(v):
        # per-block min of a per-point vector; +inf padding only feeds this
        # derived reduction, never the exact compute path
        vp = jnp.full((n_pad,), jnp.inf, jnp.float32).at[:n].set(v)
        return jnp.min(vp.reshape(nb, bb), axis=1)

    def cond(carry):
        _, it, shift, *_ = carry
        return jnp.logical_and(it < max_iters, shift > tol)

    def body(carry):
        c, it, _, idx, margin, dacc, skips = carry
        skip_b = bounds_may_skip(margin, dacc)                      # (nb,)
        d2, labels, mind = full_assign(c)
        second = bound_second_best(d2, labels)
        new_margin = block_min(bound_gap(mind, second, w > 0.0))
        skip_rows = jnp.repeat(skip_b, bb, total_repeat_length=n_pad)[:n]
        idx = jnp.where(skip_rows, idx, labels)
        margin = jnp.where(skip_b, margin, new_margin)
        sums, counts = centroid_update_ref(x, idx, w, k)
        new_c = divide_or_keep(sums, counts, c)
        shift = centroid_shift(new_c, c)
        dacc = jnp.where(skip_b, dacc + shift, shift)
        skips = skips.at[it, 0].set(jnp.sum(skip_b.astype(jnp.int32)))
        skips = skips.at[it, 1].set(nb)
        return new_c, it + 1, shift, idx, margin, dacc, skips

    init = (centroids.astype(jnp.float32), jnp.int32(0),
            jnp.float32(jnp.inf), jnp.zeros((n,), jnp.int32),
            jnp.full((nb,), -jnp.inf, jnp.float32),
            jnp.zeros((nb,), jnp.float32),
            jnp.zeros((iters_rows, 2), jnp.int32))
    final_c, iters, shift, _, _, _, skips = jax.lax.while_loop(
        cond, body, init)
    _, mind = assign_ref(points, final_c)
    return final_c, jnp.sum(w * mind), iters, shift <= tol, skips


def lloyd_solve_ref(points: jnp.ndarray, centroids: jnp.ndarray,
                    weights: jnp.ndarray | None = None,
                    *, max_iters: int = 300, tol: float = 1e-6):
    """Oracle for the resident kernel: a whole Lloyd solve ->
    (centroids (k,d) f32, sse () f32, iters () i32, converged () bool).

    Same loop semantics as ``core.kmeans``'s host solver — iterate while
    ``iters < max_iters and shift > tol`` with keep-old-centroid handling of
    empty clusters, then score the final centroids with one more assignment
    pass — composed from the single-step oracles above so the resident
    kernel's on-chip loop is tested against exactly what the host loop does.
    """
    # deferred: core imports the kernels package at its own import time
    from repro.core.metrics import centroid_shift
    w = (jnp.ones(points.shape[0], jnp.float32) if weights is None
         else weights.astype(jnp.float32))

    def cond(carry):
        c, it, shift = carry
        return jnp.logical_and(it < max_iters, shift > tol)

    def body(carry):
        c, it, _ = carry
        sums, counts, _ = lloyd_step_ref(points, c, w)
        new_c = divide_or_keep(sums, counts, c)
        return new_c, it + 1, centroid_shift(new_c, c)

    init = (centroids.astype(jnp.float32), jnp.int32(0),
            jnp.float32(jnp.inf))
    final_c, iters, shift = jax.lax.while_loop(cond, body, init)
    _, mind = assign_ref(points, final_c)
    return final_c, jnp.sum(w * mind), iters, shift <= tol
