"""Pallas TPU kernel: batched-resident S2 megakernel — one pipelined launch
per reducer *stack*.

The resident kernel (``resident.py``) runs one subset's whole Lloyd loop in a
single launch, but a device's S2 workload is a STACK of M reducers, and
``jax.vmap`` turns the stack into a serialized grid of single-block kernels:
no overlap between streaming subset g+1 from HBM and iterating subset g, and
paper-sized subsets (a few hundred points) drive the MXU with tiny matmuls at
a few percent utilization.  This kernel finishes the paper's
"one single MapReduce job with much more reducers" argument at the device
level — the same many-small-tasks aggregation as Ene et al.'s fast-clustering
rounds: ONE ``pallas_call`` whose grid iterates over *groups* of T subsets,
so the per-stack launch count drops M -> ceil(M/T) and every matmul is
group-batched.

TPU mapping (grid = ``(ceil(M/T),)``, one group per step):

  * each grid step holds a ``(T, S, d)`` points block, the shared ``(k, d)``
    init centroids, and per-subset ``(T, k, d)`` carried centroids in VMEM;
    the assignment and segment-sum matmuls are ``dot_general`` contractions
    with a batch dimension over the group, so the MXU sees one
    budget-sized batched op instead of T tiny ones;
  * the convergence loop is a single ``lax.while_loop`` over the whole
    group: per-subset (iteration count, shift) state advances only while
    that subset is still active, so each subset's trajectory is bit-for-bit
    the single-subset resident kernel's — heterogeneous convergence inside
    a group freezes finished subsets instead of perturbing them;
  * with ``reseed_empty=True`` each trip re-seeds zero-count centroids at
    the farthest in-subset points *inside* the loop: one extra
    group-batched score pass against the candidate centroids, then the
    shared ``ref.reseed_farthest`` per-lane masked-argmax selection
    (vmapped over the group), gated behind ``lax.cond`` on
    any-empty-among-active lanes — the paper-pipeline stacks that actually
    produce empty clusters keep the one-launch-per-stack property;
  * per-subset iteration/convergence state — trip counts and the
    ``shift <= tol`` predicate — is scalar state, so it leaves the kernel
    through SMEM-space ``(T, 1)`` int32 output blocks: the batched
    analogue of the single-subset kernel's SMEM scalars;
  * Pallas's automatic input pipelining double-buffers group g+1's points
    block from HBM while group g iterates — the HBM stream overlaps compute
    instead of serializing with it.

Padding: d to the 128-lane boundary, S and k to 8 sublanes (identical to
``resident_tile_shapes``); M pads up to a multiple of T with all-zero-weight
subsets that converge on their first trip and are sliced off.  Group size T
comes from the :class:`~repro.kernels.specs.DeviceProfile` VMEM budget
(:func:`batched_group_size` fills the budget instead of the ~2% one subset
uses) unless a tuned ``KernelSpec.group_t`` from the autotuning cache
overrides it — see ``kernels/tuning.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import specs
from repro.kernels.resident import (bound_block_rows, check_prune,
                                    resident_tile_shapes,
                                    resident_vmem_bytes)
from repro.kernels.specs import F32


def batched_group_vmem_bytes(t: int, s: int, d: int, k: int,
                             prune: str = "none") -> int:
    """f32 working-set bytes of one grid step holding a group of ``t``
    subsets: t subset-solve working sets plus the shared (k, d) init block.
    ``prune="bounds"`` folds each lane's bound state into the per-subset
    cost (see :func:`resident_vmem_bytes`)."""
    _, k_pad, d_pad = resident_tile_shapes(s, d, k)
    return (t * resident_vmem_bytes(s, d, k, prune=prune)
            + k_pad * d_pad * F32)


def batched_feasible(s: int, d: int, k: int,
                     budget: int | None = None,
                     prune: str = "none") -> bool:
    """Can at least a T=1 group stay VMEM-resident for this subset shape?"""
    if budget is None:
        budget = specs.get_profile().budget_bytes
    return batched_group_vmem_bytes(1, s, d, k, prune=prune) <= budget


def batched_group_size(m: int, s: int, d: int, k: int,
                       budget: int | None = None,
                       prune: str = "none") -> int:
    """Largest group size T <= M that fits the device budget (0: infeasible).

    This is the budget-filling knob: one subset's working set is typically a
    few percent of VMEM, so the group batches as many reducers per grid step
    as the :class:`DeviceProfile` budget affords — the tuner can override
    the result with a cached ``KernelSpec.group_t`` winner.  ``prune``
    charges the bound state to each lane, so pruned stacks derive a
    (slightly) smaller T instead of busting the budget.
    """
    if budget is None:
        budget = specs.get_profile().budget_bytes
    _, k_pad, d_pad = resident_tile_shapes(s, d, k)
    fixed = k_pad * d_pad * F32                   # shared init-centroid block
    per_t = resident_vmem_bytes(s, d, k, prune=prune)
    if fixed + per_t > budget:
        return 0
    return min(m, (budget - fixed) // per_t)


def _batched_kernel(x_ref, c0_ref, w_ref,
                    c_out_ref, sse_ref, iters_ref, conv_ref, skips_ref, *,
                    k_actual: int, s_actual: int, max_iters: int, tol: float,
                    carry_dtype, reseed_empty: bool, bound_block: int = 0):
    # deferred (trace-time) imports, exactly like the single-subset kernel:
    # divide_or_keep, centroid_shift and reseed_farthest have ONE definition
    # across host loop / oracle / resident kernel / this kernel — vmap gives
    # them the group batch dim, so the bit-for-bit parity contract rests on
    # shared code, not on a hand-copied formula staying in sync
    from repro.core.metrics import centroid_shift
    from repro.kernels.ref import (bound_gap, bound_second_best,
                                   bounds_may_skip, divide_or_keep,
                                   reseed_farthest)
    t, s_pad, d_pad = x_ref.shape
    k_pad = c0_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)                     # (t, s_pad, d_pad)
    w = w_ref[...].astype(jnp.float32)                     # (t, s_pad)
    x2 = jnp.sum(x * x, axis=2)                            # (t, s_pad)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, s_pad, k_pad), 2)
    kk = min(k_actual, s_actual)                           # reseed candidates

    def score_points(c):
        """Masked per-lane score matrix + min distances against ``c``: the
        group-batched MXU contraction every pass is built from."""
        cn = jnp.sum(c * c, axis=2)[:, None, :]            # (t, 1, k_pad)
        xc = jax.lax.dot_general(
            x, c, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)            # (t, s_pad, k_pad)
        s = cn - 2.0 * xc
        s = jnp.where(col < k_actual, s, jnp.inf)          # mask padded centroids
        best = jnp.min(s, axis=2)
        mind = jnp.maximum(best + x2, 0.0)                 # row-constant restored
        return s, mind

    def assign_and_reduce(c):
        """One group-batched Lloyd pass -> (sums, counts, sse) — the
        single-subset resident pass with a batch dim over the group, so the
        MXU contractions are (t, s, d) x (t, k, d) batched dots."""
        s, mind = score_points(c)
        idx = jnp.argmin(s, axis=2).astype(jnp.int32)
        onehot = (idx[:, :, None] == col).astype(jnp.float32) * w[:, :, None]
        sums = jax.lax.dot_general(
            onehot, x, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)            # (t, k_pad, d_pad)
        counts = jnp.sum(onehot, axis=1)                   # (t, k_pad)
        return sums, counts, jnp.sum(w * mind, axis=1)     # sse (t,)

    def reseed(new_c, counts, active):
        """In-kernel farthest-point reseed, per lane: one extra group-batched
        score pass against the candidate centroids, then the shared
        ``reseed_farthest`` selection (a per-lane masked argmax chain over
        the group's score matrix) vmapped over the group.  Lanes without
        empties pass through untouched (all-False ``take``), so the
        bit-for-bit contract with the single-subset kernel holds lane by
        lane.  Gated behind ``lax.cond`` on any-empty-among-active — trips
        with every cluster of every live lane populated pay nothing."""
        empty = jnp.logical_and(counts <= 0.0,
                                col[:, 0, :] < k_actual)   # (t, k_pad)

        def do_reseed(c):
            _, mind = score_points(c)
            score = jnp.where(w > 0.0, mind, -jnp.inf)     # (t, s_pad)
            take, picks = jax.vmap(
                lambda xi, si, ei: reseed_farthest(xi, si, ei, kk))(
                    x, score, empty)
            # picks round-trip the carry dtype like every centroid update
            picks = picks.astype(carry_dtype).astype(jnp.float32)
            return jnp.where(take[:, :, None], picks, c)

        fire = jnp.any(jnp.logical_and(empty, active[:, None]))
        return jax.lax.cond(fire, do_reseed, lambda c: c, new_c)

    def update_centroids(c, idx, active):
        """Group-batched segment-sum + division from a full assignment
        tensor — ONE expression for the exact and pruned loops, so a skipped
        block's cached assignments contribute bitwise what a fresh pass
        would have (the pruned-parity argument, lane by lane)."""
        onehot = (idx[:, :, None] == col).astype(jnp.float32) * w[:, :, None]
        sums = jax.lax.dot_general(
            onehot, x, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)            # (t, k_pad, d_pad)
        counts = jnp.sum(onehot, axis=1)                   # (t, k_pad)
        new_c = jax.vmap(divide_or_keep)(sums, counts, c)
        # round-trip through the caller's carry dtype so feasible, fallback
        # and single-subset solves are bit-for-bit consistent (f32 identity)
        new_c = new_c.astype(carry_dtype).astype(jnp.float32)
        if reseed_empty:
            new_c = reseed(new_c, counts, active)
        return new_c

    def cond(carry):
        _, it, shift = carry[:3]
        return jnp.any(jnp.logical_and(it < max_iters, shift > tol))

    def body(carry):
        c, it, shift = carry
        # per-subset activity: a converged (or max-iters) subset's centroids,
        # trip count and shift freeze while its groupmates keep iterating —
        # this is what makes each lane bit-for-bit the single-subset solve
        active = jnp.logical_and(it < max_iters, shift > tol)        # (t,)
        s, _ = score_points(c)
        idx = jnp.argmin(s, axis=2).astype(jnp.int32)
        new_c = update_centroids(c, idx, active)
        new_shift = jax.vmap(centroid_shift)(new_c, c)
        c = jnp.where(active[:, None, None], new_c, c)
        it = it + active.astype(jnp.int32)
        shift = jnp.where(active, new_shift, shift)
        return c, it, shift

    c0 = jnp.broadcast_to(c0_ref[...].astype(jnp.float32),
                          (t, k_pad, d_pad))
    iters_rows = skips_ref.shape[1]
    init3 = (c0, jnp.zeros((t,), jnp.int32),
             jnp.full((t,), jnp.inf, jnp.float32))

    if not bound_block:
        final_c, final_it, final_shift = jax.lax.while_loop(
            cond, body, init3)
        skips_ref[...] = jnp.zeros((1, iters_rows, 2), jnp.int32)
    else:
        # ---- bound-gated block skipping (prune="bounds") ----
        # Same triangle-inequality gate as the single-subset kernel, but a
        # block here is a (t, bound_block) slab shared by the whole group:
        # it is skipped only when EVERY lane clears it — an active lane's
        # stored margin beats twice its accumulated drift, or the lane is
        # frozen (its update is discarded by the ``where(active)`` masks, so
        # whatever its cached assignments produce is dead work either way).
        # Skipped slabs reuse cached assignments; the group-batched
        # segment-sum is the SAME contraction either way, so every active
        # lane stays bit-for-bit the exact solve.
        bb = bound_block
        nb = s_pad // bb
        colb = col[:, :bb, :]                              # (t, bb, k_pad)

        def score_blocks(c, idx, margin, skip_b):
            cn = jnp.sum(c * c, axis=2)[:, None, :]        # (t, 1, k_pad)

            def blk(b, carry):
                def compute(args):
                    idx, margin = args
                    xb = jax.lax.dynamic_slice_in_dim(x, b * bb, bb, 1)
                    x2b = jax.lax.dynamic_slice_in_dim(x2, b * bb, bb, 1)
                    wb = jax.lax.dynamic_slice_in_dim(w, b * bb, bb, 1)
                    sc = cn - 2.0 * jax.lax.dot_general(
                        xb, c, (((2,), (2,)), ((0,), (0,))),
                        preferred_element_type=jnp.float32)
                    sc = jnp.where(colb < k_actual, sc, jnp.inf)
                    ib = jnp.argmin(sc, axis=2).astype(jnp.int32)
                    gap = bound_gap(jnp.min(sc, axis=2) + x2b,
                                    bound_second_best(sc, ib) + x2b,
                                    wb > 0.0)              # (t, bb)
                    idx = jax.lax.dynamic_update_slice_in_dim(
                        idx, ib, b * bb, 1)
                    margin = jax.lax.dynamic_update_slice_in_dim(
                        margin, jnp.min(gap, axis=1)[:, None], b, 1)
                    return idx, margin

                return jax.lax.cond(skip_b[b], lambda a: a, compute, carry)

            return jax.lax.fori_loop(0, nb, blk, (idx, margin))

        def body_pruned(carry):
            c, it, shift, trip, idx, margin, dacc, skips = carry
            active = jnp.logical_and(it < max_iters, shift > tol)    # (t,)
            lane_ok = jnp.logical_or(
                jnp.logical_not(active)[:, None],
                bounds_may_skip(margin, dacc))             # (t, nb)
            skip_b = jnp.all(lane_ok, axis=0)              # (nb,)
            idx, margin = score_blocks(c, idx, margin, skip_b)
            new_c = update_centroids(c, idx, active)
            new_shift = jax.vmap(centroid_shift)(new_c, c)
            # drift state advances only on active lanes; frozen lanes keep
            # their (now irrelevant) margins — they skip via ~active
            dacc = jnp.where(
                active[:, None],
                jnp.where(skip_b[None, :], dacc + new_shift[:, None],
                          new_shift[:, None]),
                dacc)
            c = jnp.where(active[:, None, None], new_c, c)
            it = it + active.astype(jnp.int32)
            shift = jnp.where(active, new_shift, shift)
            # counters weight blocks by live lanes so a mostly-converged
            # group reads as mostly-skipped, matching the work it does
            n_act = jnp.sum(active.astype(jnp.int32))
            skips = skips.at[trip, 0].set(
                jnp.sum(skip_b.astype(jnp.int32)) * n_act)
            skips = skips.at[trip, 1].set(nb * n_act)
            return c, it, shift, trip + 1, idx, margin, dacc, skips

        init = init3 + (jnp.int32(0),
                        jnp.zeros((t, s_pad), jnp.int32),
                        jnp.full((t, nb), -jnp.inf, jnp.float32),
                        jnp.zeros((t, nb), jnp.float32),
                        jnp.zeros((iters_rows, 2), jnp.int32))
        final_c, final_it, final_shift, _, _, _, _, skips = \
            jax.lax.while_loop(cond, body_pruned, init)
        skips_ref[...] = skips[None]

    # final statistics with the converged centroids — one extra group-batched
    # assignment pass that never leaves VMEM
    _, _, final_sse = assign_and_reduce(final_c)
    c_out_ref[...] = final_c
    sse_ref[...] = final_sse[:, None]
    # per-subset (trip count, converged) state is scalar state, so its
    # output blocks live in SMEM (see out_specs); t is static — the scalar
    # stores unroll
    for u in range(t):
        iters_ref[u, 0] = final_it[u]
        conv_ref[u, 0] = jnp.where(final_shift[u] <= tol, 1, 0)


@functools.partial(jax.jit,
                   static_argnames=("group_t", "max_iters", "tol",
                                    "interpret", "reseed_empty", "prune",
                                    "bound_block"))
def _lloyd_solve_batched(subsets: jnp.ndarray,
                         centroids: jnp.ndarray,
                         weights: jnp.ndarray | None = None,
                         *,
                         group_t: int,
                         max_iters: int = 300,
                         tol: float = 1e-6,
                         interpret: bool = False,
                         reseed_empty: bool = False,
                         prune: str = "none",
                         bound_block: int | None = None):
    m, s, d = subsets.shape
    k = centroids.shape[0]
    t = max(1, min(int(group_t), m))
    s_pad, k_pad, d_pad = resident_tile_shapes(s, d, k)
    m_pad = -(-m // t) * t                    # pad with zero-weight subsets
    bb = bound_block_rows(s_pad, bound_block) if prune == "bounds" else 0
    iters_rows = max(int(max_iters), 1)
    n_groups = m_pad // t

    x = jnp.zeros((m_pad, s_pad, d_pad), subsets.dtype)
    x = x.at[:m, :s, :d].set(subsets)
    c = jnp.zeros((k_pad, d_pad), centroids.dtype).at[:k, :d].set(centroids)
    w = jnp.zeros((m_pad, s_pad), jnp.float32)
    w = w.at[:m, :s].set(1.0 if weights is None
                         else weights.astype(jnp.float32))

    c_out, sse, iters, conv, skips = pl.pallas_call(
        functools.partial(_batched_kernel, k_actual=k, s_actual=s,
                          max_iters=max_iters, tol=tol,
                          carry_dtype=centroids.dtype,
                          reseed_empty=reseed_empty, bound_block=bb),
        grid=(n_groups,),
        in_specs=[
            pl.BlockSpec((t, s_pad, d_pad), lambda g: (g, 0, 0)),
            pl.BlockSpec((k_pad, d_pad), lambda g: (0, 0)),
            pl.BlockSpec((t, s_pad), lambda g: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, k_pad, d_pad), lambda g: (g, 0, 0)),
            pl.BlockSpec((t, 1), lambda g: (g, 0)),
            # per-subset (trips, converged) is scalar loop state -> SMEM
            pl.BlockSpec((t, 1), lambda g: (g, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((t, 1), lambda g: (g, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, iters_rows, 2), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, k_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_groups, iters_rows, 2), jnp.int32),
        ],
        interpret=interpret,
    )(x, c, w)

    # per-group counters sum into one stack-level (max_iters, 2) trajectory
    return (c_out[:m, :k, :d].astype(centroids.dtype), sse[:m, 0],
            iters[:m, 0], conv[:m, 0].astype(bool), jnp.sum(skips, axis=0))


def lloyd_solve_batched(subsets: jnp.ndarray,
                        centroids: jnp.ndarray,
                        weights: jnp.ndarray | None = None,
                        *,
                        group_t: int | None = None,
                        max_iters: int = 300,
                        tol: float = 1e-6,
                        interpret: bool | None = None,
                        spec: specs.KernelSpec | None = None,
                        reseed_empty: bool = False,
                        prune: str = "none",
                        bound_block: int | None = None,
                        return_skips: bool = False):
    """A whole STACK of Lloyd solves in ONE kernel launch:
    (M,S,d),(k,d)[,(M,S)] -> (centroids (M,k,d), sse (M,), iters (M,) i32,
    converged (M,) bool).

    Per-subset semantics are exactly :func:`~repro.kernels.resident
    .lloyd_solve_resident`'s — same stop criterion, same keep-old-centroid
    policy, same carry-dtype round-trip, same in-kernel farthest-point
    reseed under ``reseed_empty=True`` — so every lane matches the
    vmap-of-resident oracle bit-for-bit, including groups whose subsets
    converge at different iterations.  ``group_t`` is the subsets-per-grid-
    step batch (default: fill the DeviceProfile budget via
    :func:`batched_group_size`; a :class:`KernelSpec` with ``group_t`` set —
    the tuner's cached winner — overrides).  When no ``group_t`` is given
    and even a T=1 group busts the budget this raises ``ValueError`` rather
    than launching over budget — check :func:`batched_feasible` first; the
    ``batched`` engine does, and falls back to the vmap-of-solve path.
    An explicit ``group_t`` is always honored (interpret-mode benches and
    tests rely on that).

    ``prune="bounds"`` turns on the bound-gated block skipping of the
    single-subset kernel at group granularity: a (T, bound_block) slab of
    points skips its score pass when every live lane's stored margin clears
    twice its accumulated drift — results stay bit-for-bit the exact
    stack's.  ``return_skips=True`` appends a ``(max_iters, 2)`` int32
    counter, [lane-blocks skipped, lane-blocks live] per iteration summed
    over groups (all zeros for ``prune="none"``).
    """
    check_prune(prune)
    m, s, d = subsets.shape
    k = centroids.shape[0]
    if group_t is None and spec is not None:
        group_t = spec.group_t
    if group_t is None:
        group_t = batched_group_size(m, s, d, k, prune=prune)
        if group_t <= 0:
            # never silently clamp an infeasible auto-derivation to T=1 and
            # launch over budget — an explicit group_t is the caller taking
            # responsibility (interpret-mode benches do), absence is not
            raise ValueError(
                f"no feasible group size for stack (m={m}, s={s}, d={d}, "
                f"k={k}): one subset's solve working set "
                f"({resident_vmem_bytes(s, d, k)} B) busts the device "
                f"budget ({specs.get_profile().budget_bytes} B) — check "
                f"batched_feasible() first and fall back to vmap-of-solve "
                f"(the 'batched' engine does this automatically)")
    if interpret is None:
        interpret = (spec.interpret if spec is not None
                     and spec.interpret is not None else False)
    out = _lloyd_solve_batched(subsets, centroids, weights,
                               group_t=int(group_t),
                               max_iters=max_iters, tol=tol,
                               interpret=bool(interpret),
                               reseed_empty=bool(reseed_empty),
                               prune=prune, bound_block=bound_block)
    return out if return_skips else out[:4]
