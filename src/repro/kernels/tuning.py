"""Offline block-geometry autotuning + the ``tuned`` Lloyd engine.

The paper trades a one-off preprocessing pass (the k-d tree) for every
subsequent reducer running at full speed; this module makes the same trade
for kernel geometry — like Bahmani et al.'s Scalable K-Means++ trades rounds
for per-round work, one offline sweep buys every later solve the fastest
tile shape the chip admits:

  * :func:`candidate_specs` builds the sweep grid for a launch shape and
    prunes it by VMEM feasibility (``KernelSpec.fused_vmem_bytes`` vs the
    chip's :class:`~repro.kernels.specs.DeviceProfile` budget) and by
    effective-geometry duplicates (clamping makes ``block_n=512`` and ``256``
    identical at ``n=300`` — no point timing both);
  * :func:`autotune_step` times one fused Lloyd pass per surviving candidate
    and records the winner;
  * :func:`autotune_batched` sweeps the batched-resident megakernel's
    group-size axis (``candidate_group_ts``: the static GROUP_TS grid plus
    the budget-derived fill-the-budget point) for a whole (m, s, d, k)
    reducer stack and persists a winner whose ``KernelSpec.group_t`` is set,
    keyed with the ``|m<bucket>`` stack extension — the ``batched`` engine's
    group sizing consults it via :func:`lookup_group_t`;
  * :class:`TuningCache` persists winners as JSON under
    ``experiments/tuning/kernel_specs.json`` (``REPRO_TUNING_CACHE``
    overrides the path), keyed by
    ``device_kind|dtype|n<bucket>|d<d>|k<k>`` where the n-bucket is the
    next power of two — solves of a given problem family hit one entry;
  * :class:`TunedEngine` (registered as ``tuned``) is the consumer:
    fused/resident behaviour whose ``resolve_spec`` hook returns the cached
    winner for the launch shape, falling back to the module defaults when no
    entry exists — so ``backend="tuned"`` is always safe to request, tuned
    or not.

Drive the sweep with ``python -m repro.launch.autotune``; benchmarks/
kernel_bench.py reports tuned-vs-default head-to-head.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.kernels import engine as engine_mod
from repro.kernels import specs
from repro.kernels.specs import DeviceProfile, KernelSpec

ENV_CACHE_PATH = "REPRO_TUNING_CACHE"
CACHE_VERSION = 1

# sweep grid defaults: sublane-aligned powers of two around the MXU shape
BLOCK_NS = (64, 128, 256, 512)
BLOCK_KS = (64, 128, 256)
# group sizes for the batched-resident stack sweep (the budget-derived
# maximum always joins the grid, so big-VMEM chips are never under-swept)
GROUP_TS = (1, 2, 4, 8, 16)


def default_cache_path() -> Path:
    env = os.environ.get(ENV_CACHE_PATH)
    if env:
        return Path(env)
    return (Path(__file__).resolve().parents[3]
            / "experiments" / "tuning" / "kernel_specs.json")


def n_bucket(n: int) -> int:
    """Shape-family bucket for n: the next power of two (min 8).  d and k
    change the kernel's inner geometry so they key exactly; n only scales
    the grid's major axis, so nearby n share a winner."""
    return max(8, 1 << max(0, int(n - 1).bit_length()))


def cache_key(device_kind: str, dtype, n: int, d: int, k: int,
              m: int | None = None, kernel: str | None = None) -> str:
    """``m`` extends the key for batched-STACK entries (n is then the subset
    size, m the stack's reducer count, bucketed like n); ``kernel`` extends
    it for non-Lloyd kernel families (``"init"``: the k-means|| round sweep,
    where k is the candidate-tile capacity, bucketed like n — capacities are
    power-of-two padded, so nearby pools share a winner) — single-solve keys
    are unchanged, so version-1 caches keep resolving."""
    dt = jnp.dtype(dtype).name
    kk = n_bucket(k) if kernel == "init" else k
    key = f"{device_kind.lower().strip()}|{dt}|n{n_bucket(n)}|d{d}|k{kk}"
    if m is not None:
        key = f"{key}|m{n_bucket(m)}"
    return key if kernel is None else f"{key}|{kernel}"


@dataclasses.dataclass
class TuningCache:
    """The persisted winners: ``key -> KernelSpec`` (+ sweep metadata).

    JSON schema (``version`` 1)::

        {"version": 1,
         "entries": {"<device>|<dtype>|n<bucket>|d<d>|k<k>":
                       {"block_n": 256, "block_k": 128,
                        "acc_dtype": "float32",
                        "time_us": 812.4, "n": 300, "d": 2, "k": 5,
                        "candidates": 9}}}
    """

    path: Path
    entries: dict[str, dict] = dataclasses.field(default_factory=dict)

    @classmethod
    def load(cls, path: str | Path | None = None) -> "TuningCache":
        p = Path(path) if path is not None else default_cache_path()
        entries: dict[str, dict] = {}
        if p.exists():
            try:
                obj = json.loads(p.read_text())
                if obj.get("version") == CACHE_VERSION:
                    entries = dict(obj.get("entries", {}))
                else:
                    warnings.warn(f"ignoring tuning cache {p}: version "
                                  f"{obj.get('version')!r} != {CACHE_VERSION}")
            except (OSError, json.JSONDecodeError, AttributeError) as e:
                warnings.warn(f"ignoring unreadable tuning cache {p}: {e}")
        return cls(path=p, entries=entries)

    def get(self, key: str) -> KernelSpec | None:
        entry = self.entries.get(key)
        if entry is None:
            return None
        try:
            return KernelSpec.from_json(entry)
        except (KeyError, ValueError, TypeError) as e:
            warnings.warn(f"ignoring malformed tuning entry {key!r}: {e}")
            return None

    def put(self, key: str, spec: KernelSpec, **meta) -> None:
        self.entries[key] = {**spec.to_json(), **meta}

    def save(self) -> Path:
        """Atomic write (tmp + rename) so a crashed sweep never truncates
        the winners every later process would read."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = json.dumps({"version": CACHE_VERSION,
                              "entries": self.entries}, indent=2,
                             sort_keys=True)
        tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        tmp.write_text(payload)
        os.replace(tmp, self.path)
        return self.path


# process-wide cache memo: loaded lazily, keyed by resolved path so tests
# that repoint REPRO_TUNING_CACHE get a fresh view
_ACTIVE: TuningCache | None = None


def _active_cache() -> TuningCache:
    global _ACTIVE
    want = default_cache_path()
    if _ACTIVE is None or _ACTIVE.path != want:
        _ACTIVE = TuningCache.load(want)
    return _ACTIVE


def reload_cache() -> TuningCache:
    """Drop the in-process memo (after a sweep wrote new winners)."""
    global _ACTIVE
    _ACTIVE = None
    return _active_cache()


def lookup_spec(n: int, d: int, k: int, dtype=jnp.float32,
                device_kind: str | None = None,
                m: int | None = None) -> KernelSpec | None:
    """Cached winner for this launch shape, or ``None`` (use defaults).

    Pure host-side work on static shape/dtype info — safe at trace time,
    which is when engines call it.  With ``m``, resolves the batched-stack
    entry (n = subset size, m = reducers in the stack) instead.
    """
    kind = device_kind or specs.get_profile().device_kind
    return _active_cache().get(cache_key(kind, dtype, n, d, k, m=m))


def lookup_group_t(s: int, d: int, k: int, m: int, dtype=jnp.float32,
                   device_kind: str | None = None) -> int | None:
    """Tuned group size for an (m, s, d, k) reducer stack, or ``None``
    (budget-derived) — what the ``batched`` engine's group sizing consults."""
    spec = lookup_spec(s, d, k, dtype, device_kind, m=m)
    return None if spec is None else spec.group_t


def lookup_init_spec(n: int, d: int, c: int, dtype=jnp.float32,
                     device_kind: str | None = None) -> KernelSpec | None:
    """Cached winner for the k-means|| init-sweep kernel at (n points, d
    dims, c candidate-tile capacity), or ``None`` (module defaults) — what
    ``core.init.kmeans_parallel_init`` consults when no spec is pinned."""
    kind = device_kind or specs.get_profile().device_kind
    return _active_cache().get(cache_key(kind, dtype, n, d, c,
                                         kernel="init"))


# ------------------------------------------------------------------ sweep ---

def candidate_specs(n: int, d: int, k: int,
                    profile: DeviceProfile | None = None,
                    block_ns=BLOCK_NS, block_ks=BLOCK_KS,
                    acc_dtypes=("float32",),
                    vmem_bytes: str = "fused_vmem_bytes") -> list[KernelSpec]:
    """The pruned sweep grid for one launch shape.

    Prunes (a) geometries whose working set busts the device budget —
    priced by the ``KernelSpec`` estimator named by ``vmem_bytes``
    (``fused_vmem_bytes`` for the Lloyd sweep, ``init_vmem_bytes`` for the
    k-means|| init sweep, where ``k`` is the candidate-tile capacity) — and
    (b) duplicates — block sizes clamp to the problem, so distinct
    (block_n, block_k) pairs often launch identical tiles.  The module
    default always competes (and survives even if the budget would prune
    it, so the sweep can never return an empty grid).
    """
    profile = profile or specs.get_profile()
    out: dict[tuple, KernelSpec] = {}
    for acc in acc_dtypes:
        for bn in block_ns:
            for bk in block_ks:
                cand = KernelSpec(block_n=bn, block_k=bk, acc_dtype=acc)
                if getattr(cand, vmem_bytes)(n, d, k) > profile.budget_bytes:
                    continue
                out.setdefault((cand.tile_shapes(n, d, k), acc), cand)
    fallback = specs.DEFAULT_SPEC.replace(acc_dtype=acc_dtypes[0])
    out.setdefault((fallback.tile_shapes(n, d, k), fallback.acc_dtype),
                   fallback)
    return list(out.values())


def _timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds with block_until_ready (local copy — src/ must
    not depend on the benchmarks package)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def autotune_step(n: int, d: int, k: int, *,
                  dtype=jnp.float32,
                  profile: DeviceProfile | None = None,
                  cache: TuningCache | None = None,
                  repeats: int = 3,
                  interpret: bool | None = None,
                  block_ns=BLOCK_NS, block_ks=BLOCK_KS,
                  acc_dtypes=("float32",),
                  measure=None,
                  seed: int = 0):
    """Sweep the candidate grid for one (n, d, k, dtype) and record the
    winner in ``cache`` (caller saves).  Returns ``(best_spec, rows)`` where
    ``rows`` is the full sweep table for reporting.

    ``measure(spec) -> seconds`` may be injected (tests, exotic harnesses);
    the default times one fused Lloyd pass on synthetic data.  On non-TPU
    hosts the kernels run interpreted, so wall-clock there only orders the
    Python interpreter — the sweep still exercises every geometry end to
    end, which is what the CI smoke checks.
    """
    profile = profile or specs.get_profile()
    cands = candidate_specs(n, d, k, profile,
                            block_ns=block_ns, block_ks=block_ks,
                            acc_dtypes=acc_dtypes)
    if measure is None:
        from repro.kernels import ops
        kx, kc = jax.random.split(jax.random.key(seed + n * d * k))
        x = jax.random.normal(kx, (n, d), jnp.float32).astype(dtype)
        c = jax.random.normal(kc, (k, d), jnp.float32).astype(dtype)

        def measure(spec):
            return _timeit(
                lambda: ops.lloyd_step_fused(x, c, spec=spec,
                                             interpret=interpret),
                repeats=repeats)

    rows = []
    for cand in cands:
        secs = measure(cand)
        rows.append({"spec": cand, "time_us": secs * 1e6,
                     "vmem_bytes": cand.fused_vmem_bytes(n, d, k)})
    rows.sort(key=lambda r: r["time_us"])
    best = rows[0]
    key = cache_key(profile.device_kind, dtype, n, d, k)
    if cache is not None:
        cache.put(key, best["spec"], time_us=round(best["time_us"], 2),
                  n=n, d=d, k=k, candidates=len(cands))
    return best["spec"], rows


def autotune_init_sweep(n: int, d: int, c: int, *,
                        dtype=jnp.float32,
                        ell: float | None = None,
                        profile: DeviceProfile | None = None,
                        cache: TuningCache | None = None,
                        repeats: int = 3,
                        interpret: bool | None = None,
                        block_ns=BLOCK_NS, block_ks=BLOCK_KS,
                        acc_dtypes=("float32",),
                        measure=None,
                        seed: int = 0):
    """Sweep the candidate grid for the k-means|| init-sweep kernel at one
    (n points, d dims, c candidate-tile capacity) shape and record the
    winner under the ``|init``-extended cache key.  Returns ``(best_spec,
    rows)``.

    The init sweep streams the points against a SMALL resident candidate
    tile (~ell candidates, power-of-two padded), so its best geometry is
    not the Lloyd sweep's: the candidate axis usually fits one block and
    the win is all in ``block_n``.  ``measure(spec) -> seconds`` may be
    injected; the default times one full round sweep on synthetic data.
    """
    profile = profile or specs.get_profile()
    cands = candidate_specs(n, d, c, profile,
                            block_ns=block_ns, block_ks=block_ks,
                            acc_dtypes=acc_dtypes,
                            vmem_bytes="init_vmem_bytes")
    ell = float(2 * c) if ell is None else float(ell)
    if measure is None:
        from repro.kernels import ops
        kx, kc, ku = jax.random.split(jax.random.key(seed + n * d * c), 3)
        x = jax.random.normal(kx, (n, d), jnp.float32).astype(dtype)
        cd = jax.random.normal(kc, (c, d), jnp.float32).astype(dtype)
        u = jax.random.uniform(ku, (n,), jnp.float32)
        om = jnp.full((n,), jnp.inf, jnp.float32)
        pp = jnp.float32(1.0)

        def measure(spec):
            return _timeit(
                lambda: ops.init_sweep(x, cd, om, u, pp, ell=ell,
                                       spec=spec, interpret=interpret),
                repeats=repeats)

    rows = []
    for cand in cands:
        rows.append({"spec": cand, "time_us": measure(cand) * 1e6,
                     "vmem_bytes": cand.init_vmem_bytes(n, d, c)})
    rows.sort(key=lambda r: r["time_us"])
    best = rows[0]
    if cache is not None:
        cache.put(cache_key(profile.device_kind, dtype, n, d, c,
                            kernel="init"),
                  best["spec"], time_us=round(best["time_us"], 2),
                  n=n, d=d, k=c, candidates=len(cands))
    return best["spec"], rows


def candidate_group_ts(m: int, s: int, d: int, k: int,
                       profile: DeviceProfile | None = None,
                       group_ts=GROUP_TS,
                       prune: str = "none") -> list[int]:
    """The pruned group-size grid for one (m, s, d, k) reducer stack.

    Prunes groups whose per-grid-step working set busts the device budget
    and clamps to the stack size; the budget-derived maximum
    (``batched_group_size``) always competes so the sweep covers the
    fill-the-budget point even when the static grid stops short.  Returns
    ``[]`` when even a single subset does not fit (the engine's fallback).
    ``prune`` charges the bound state to every candidate's working set.
    """
    from repro.kernels import batch_resident
    profile = profile or specs.get_profile()
    cap = batch_resident.batched_group_size(m, s, d, k, profile.budget_bytes,
                                            prune=prune)
    if cap <= 0:
        return []
    out = []
    for t in group_ts:
        t = min(int(t), m)
        if t >= 1 and t <= cap and t not in out:
            out.append(t)
    if cap not in out and cap <= m:
        out.append(cap)
    return sorted(out)


def autotune_batched(m: int, s: int, d: int, k: int, *,
                     dtype=jnp.float32,
                     profile: DeviceProfile | None = None,
                     cache: TuningCache | None = None,
                     repeats: int = 3,
                     interpret: bool | None = None,
                     group_ts=GROUP_TS,
                     solve_iters: int = 8,
                     reseed_empty: bool = False,
                     prune: str = "none",
                     measure=None,
                     seed: int = 0):
    """Sweep the group-size axis of the batched-resident megakernel for one
    (m, s, d, k) stack and record the winner (a spec whose ``group_t`` is
    set) under the ``|m<bucket>``-extended cache key.  Returns
    ``(best_spec | None, rows)`` — ``None`` when no group fits VMEM.

    ``measure(t) -> seconds`` may be injected; the default times one whole
    fixed-trip stack solve (``tol=0`` so every candidate pays identical
    iteration counts).  ``reseed_empty`` times the in-kernel reseed path
    instead — the paper-pipeline configuration — under the SAME cache key:
    group size is a geometry knob, and the reseed pass scales with the
    group exactly like the assignment pass it mirrors.  ``prune`` likewise
    times (and budget-prunes) the bound-gated skipping variant under the
    same key — results are bitwise identical either way, only the timing
    and the bound-state bytes differ.
    """
    from repro.kernels import batch_resident
    from repro.kernels.resident import check_prune
    check_prune(prune)
    profile = profile or specs.get_profile()
    cands = candidate_group_ts(m, s, d, k, profile, group_ts, prune=prune)
    if not cands:
        return None, []
    if measure is None:
        from repro.kernels import ops
        kx, kc = jax.random.split(jax.random.key(seed + m * s * d * k))
        x = jax.random.normal(kx, (m, s, d), jnp.float32).astype(dtype)
        c = jax.random.normal(kc, (k, d), jnp.float32).astype(dtype)

        def measure(t):
            return _timeit(
                lambda: ops.lloyd_solve_batched(
                    x, c, group_t=t, max_iters=solve_iters, tol=0.0,
                    interpret=interpret, reseed_empty=reseed_empty,
                    prune=prune)[0],
                repeats=repeats)

    rows = []
    for t in cands:
        rows.append({
            "group_t": t, "time_us": measure(t) * 1e6,
            "launches": -(-m // t),
            "vmem_bytes": batch_resident.batched_group_vmem_bytes(
                t, s, d, k, prune=prune),
        })
    rows.sort(key=lambda r: r["time_us"])
    best = specs.DEFAULT_SPEC.replace(group_t=rows[0]["group_t"])
    if cache is not None:
        cache.put(cache_key(profile.device_kind, dtype, s, d, k, m=m), best,
                  time_us=round(rows[0]["time_us"], 2),
                  m=m, n=s, d=d, k=k, candidates=len(cands))
    return best, rows


# ----------------------------------------------------------- tuned engine ---

class TunedEngine(engine_mod.ResidentEngine):
    """fused/resident behaviour with autotuned kernel geometry.

    Identical solve semantics to ``resident`` (VMEM-resident loop when the
    DeviceProfile says the subset fits, fused per-step loop otherwise); the
    only difference is the ``resolve_spec`` hook, which looks the launch
    shape up in the tuning cache and falls back to the module defaults on a
    miss — request ``backend="tuned"`` unconditionally, it can only match
    or beat the untuned engines."""

    name = "tuned"

    def resolve_spec(self, points, centroids):
        return lookup_spec(points.shape[0], points.shape[1],
                           centroids.shape[0], points.dtype)


engine_mod.register(TunedEngine())
