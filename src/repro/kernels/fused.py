"""Pallas TPU kernel: fused single-pass Lloyd iteration.

The two-kernel path (``assign_pallas`` then ``centroid_update_pallas``)
streams all ``n`` points from HBM twice per Lloyd iteration and round-trips
the ``(n,)`` labels and distances through HBM in between.  That is the
kernel-level analogue of PKMeans' cascaded MapReduce jobs; this kernel is the
paper's "one job" argument applied to the memory hierarchy: assignment and
accumulation happen in a *single* grid sweep, so each point tile is read from
HBM exactly once per iteration and the labels/distances never leave VMEM.

TPU mapping (grid = ``(n_blocks, k_blocks)``, k minor):

  * phase 1 (every ``j``): the same flash-attention-style online
    (best_score, best_index) reduction as ``assign.py`` — a ``(bn x d) @
    (d x bk)`` MXU matmul per step — except the running pair is carried in
    VMEM *scratch* instead of an output block, because it is iteration-local
    state, not a kernel result;
  * phase 2 (``j == k_blocks-1`` only): with the argmin now complete for this
    x-tile, build the one-hot matrix from the scratch indices and fire the
    MXU segment-sum of ``centroid_update.py`` — accumulating partial
    ``sums (k, d)``, ``counts (k,)`` and shard SSE into revisited output
    blocks that stay resident in VMEM for the whole sweep.

Block geometry arrives as a :class:`~repro.kernels.specs.KernelSpec`
(``specs.DEFAULT_SPEC`` when unset; the ``tuned`` engine feeds autotuned
winners through the same argument) — the historical loose ``block_n``/
``block_k`` ints remain as a deprecated shim.

Padding follows the other kernels: d zero-padded to the 128-lane boundary
(exact for squared euclidean), n/k padded to block multiples; padded
centroids are masked to +inf scores, padded points carry weight 0, so neither
can contaminate the accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import specs
from repro.kernels.specs import KernelSpec


def _fused_kernel(x_ref, c_ref, cn_ref,
                  *rest,
                  block_k: int, k_actual: int, last_j: int,
                  with_labels: bool, with_accum: bool, acc):
    if with_accum:    # assign-only mode streams no weights, owns no accums
        w_ref, sums_ref, counts_ref, sse_ref, *rest = rest
    if with_labels:
        labels_ref, mind_ref, best_scr, idx_scr = rest
    else:
        best_scr, idx_scr = rest
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...].astype(acc)                            # (bn, d)
    c = c_ref[...].astype(acc)                            # (bk, d)
    cn = cn_ref[...].astype(acc)                          # (1, bk)

    # --- phase 1: online argmin over centroid tiles (same as assign.py) ---
    # score = ||c||^2 - 2 x.c   (row-constant ||x||^2 omitted)
    s = (cn - 2.0 * jnp.dot(x, c.T, preferred_element_type=acc)
         ).astype(jnp.float32)
    col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < k_actual, s, jnp.inf)             # mask padded centroids

    local_best = jnp.min(s, axis=1)                       # (bn,)
    local_idx = (jnp.argmin(s, axis=1).astype(jnp.int32) + j * block_k)

    @pl.when(j == 0)
    def _init_scratch():
        best_scr[...] = local_best
        idx_scr[...] = local_idx

    @pl.when(j > 0)
    def _accumulate_scratch():
        prev_best = best_scr[...]
        prev_idx = idx_scr[...]
        take = local_best < prev_best                     # strict: low-index ties win
        best_scr[...] = jnp.where(take, local_best, prev_best)
        idx_scr[...] = jnp.where(take, local_idx, prev_idx)

    # --- phase 2: the argmin is final — accumulate sums/counts/SSE without
    # the labels ever touching HBM (same MXU one-hot matmul as
    # centroid_update.py).  In assign-only mode (``with_accum=False``, the
    # serving hot path) the flush stops at the labels/distances: no one-hot
    # matmul, no VMEM-resident (k, d) accumulator blocks to revisit and
    # write back — the sweep does only the phase-1 reads plus two (bn,)
    # output stores per x-tile. ---
    @pl.when(j == last_j)
    def _flush():
        idx = idx_scr[...]
        # add the row-constant ||x||^2 back to recover true distances
        xf = x.astype(jnp.float32)
        x2 = jnp.sum(xf * xf, axis=1)
        mind = jnp.maximum(best_scr[...] + x2, 0.0)

        if with_labels:                                   # final-pass labels out
            labels_ref[...] = idx
            mind_ref[...] = mind

        if not with_accum:
            return

        w = w_ref[...].astype(acc)                        # (bn,)
        k_pad = sums_ref.shape[0]
        onehot = (idx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (idx.shape[0], k_pad), 1)).astype(acc)
        onehot = onehot * w[:, None]

        local_sums = jnp.dot(onehot.T, x,
                             preferred_element_type=acc).astype(jnp.float32)
        local_counts = jnp.sum(onehot.astype(jnp.float32), axis=0)[None, :]
        local_sse = jnp.sum(w.astype(jnp.float32) * mind)[None, None]  # (1, 1)

        @pl.when(i == 0)
        def _init_out():
            sums_ref[...] = local_sums
            counts_ref[...] = local_counts
            sse_ref[...] = local_sse

        @pl.when(i > 0)
        def _accumulate_out():
            sums_ref[...] += local_sums
            counts_ref[...] += local_counts
            sse_ref[...] += local_sse


def fused_tile_shapes(n: int, d: int, k: int,
                      block_n: int | None = None,
                      block_k: int | None = None,
                      spec: KernelSpec | None = None):
    """The kernel's tiling policy: (bn, bk, n_pad, k_pad, d_pad).

    Delegates to :meth:`KernelSpec.tile_shapes` — the single source of truth
    the wrapper below, the tuner's VMEM pricing, and the footprint accounting
    in benchmarks/kernel_bench.py all read, so the reported working sets
    always match what the kernel actually allocates."""
    if spec is None:
        spec = specs.DEFAULT_SPEC.replace(
            **{f: v for f, v in (("block_n", block_n), ("block_k", block_k))
               if v is not None})
    return spec.tile_shapes(n, d, k)


@functools.partial(jax.jit,
                   static_argnames=("spec", "return_labels", "assign_only"))
def _lloyd_step_fused(points: jnp.ndarray,
                      centroids: jnp.ndarray,
                      weights: jnp.ndarray | None,
                      *,
                      spec: KernelSpec,
                      return_labels: bool,
                      assign_only: bool = False):
    n, d = points.shape
    k = centroids.shape[0]
    bn, bk, n_pad, k_pad, d_pad = spec.tile_shapes(n, d, k)

    x = jnp.zeros((n_pad, d_pad), points.dtype).at[:n, :d].set(points)
    c = jnp.zeros((k_pad, d_pad), centroids.dtype).at[:k, :d].set(centroids)
    cn = jnp.sum(c.astype(jnp.float32) ** 2, axis=-1)[None, :]   # (1, k_pad)

    grid = (n_pad // bn, k_pad // bk)
    inputs = [x, c, cn]
    in_specs = [
        pl.BlockSpec((bn, d_pad), lambda i, j: (i, 0)),
        pl.BlockSpec((bk, d_pad), lambda i, j: (j, 0)),
        pl.BlockSpec((1, bk), lambda i, j: (0, j)),
    ]
    out_specs, out_shape = [], []
    if not assign_only:
        w = jnp.zeros((n_pad,), jnp.float32)
        w = w.at[:n].set(1.0 if weights is None
                         else weights.astype(jnp.float32))
        inputs.append(w)
        in_specs.append(pl.BlockSpec((bn,), lambda i, j: (i,)))
        out_specs += [
            pl.BlockSpec((k_pad, d_pad), lambda i, j: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((k_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ]
    if return_labels:
        out_specs += [pl.BlockSpec((bn,), lambda i, j: (i,)),
                      pl.BlockSpec((bn,), lambda i, j: (i,))]
        out_shape += [jax.ShapeDtypeStruct((n_pad,), jnp.int32),
                      jax.ShapeDtypeStruct((n_pad,), jnp.float32)]
    out = pl.pallas_call(
        functools.partial(_fused_kernel, block_k=bk, k_actual=k,
                          last_j=grid[1] - 1, with_labels=return_labels,
                          with_accum=not assign_only,
                          acc=jnp.dtype(spec.acc_dtype)),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),               # running best score
            pltpu.VMEM((bn,), jnp.int32),                 # running best index
        ],
        interpret=bool(spec.interpret),
    )(*inputs)

    if assign_only:
        labels, mind = out
        return labels[:n], mind[:n]
    sums, counts, sse = out[:3]
    if return_labels:
        labels, mind = out[3], out[4]
        return (sums[:k, :d], counts[0, :k], sse[0, 0],
                labels[:n], mind[:n])
    return sums[:k, :d], counts[0, :k], sse[0, 0]


def lloyd_step_fused(points: jnp.ndarray,
                     centroids: jnp.ndarray,
                     weights: jnp.ndarray | None = None,
                     *,
                     spec: KernelSpec | None = None,
                     block_n: int | None = None,
                     block_k: int | None = None,
                     interpret: bool | None = None,
                     return_labels: bool = False,
                     assign_only: bool = False):
    """One fused Lloyd pass: (n,d),(k,d)[,(n,)] ->
    sums (k,d) f32, counts (k,) f32, sse () f32.

    ``weights`` defaults to all-ones; pass a 0/1 mask (or arbitrary
    non-negative weights) to ignore padded rows.  Callers divide
    ``sums / counts`` (guarding empty clusters) to get the new centroids —
    kept outside the kernel so the division policy stays in one place
    (``ref.divide_or_keep``).

    With ``return_labels=True`` the flush phase additionally streams out the
    finished per-point ``labels (n,) i32`` and ``mind (n,) f32`` — meant for
    the *final* iteration only (cluster dumps, solver final statistics), so
    callers get the assignment from the same single sweep instead of a
    second two-kernel assign pass.  Returns a 5-tuple in that case.

    ``assign_only=True`` (implies ``return_labels``) is the serving hot
    path: the SAME phase-1 online argmin — labels/distances bit-for-bit
    with the full sweep — but the flush stops there.  No weights stream in,
    no one-hot MXU matmul fires, and the VMEM-resident ``(k_pad, d_pad)``
    sums / counts / sse output blocks are never allocated or written: the
    only stores are the two ``(bn,)`` per-tile vectors, roughly halving
    per-sweep VMEM writes for query batches that want labels, not a
    centroid update.  Returns ``(labels (n,) i32, mind (n,) f32)``.
    """
    spec = specs.coerce(spec, block_n=block_n, block_k=block_k,
                        interpret=interpret)
    spec = spec.with_interpret(bool(spec.interpret))
    if assign_only:
        if weights is not None:
            raise ValueError("assign_only sweeps take no weights: the "
                             "accumulators that would consume them are "
                             "exactly what the mode elides")
        return _lloyd_step_fused(points, centroids, None, spec=spec,
                                 return_labels=True, assign_only=True)
    return _lloyd_step_fused(points, centroids, weights, spec=spec,
                             return_labels=return_labels)
