"""Kernel geometry ownership: :class:`KernelSpec` + :class:`DeviceProfile`.

Every Pallas kernel in this package tiles the same way — an ``(n, d)`` point
stream against a ``(k, d)`` centroid set — and until this module existed each
kernel file froze its own copy of the block geometry (``block_n=256`` /
``block_k=128`` module defaults) while the resident engine guessed a 12 MiB
VMEM budget.  The paper's speedup rests on each reducer running as fast as
the hardware allows; the TPU analogue of that claim is *kernel geometry*, so
geometry now has exactly one owner:

  * :class:`KernelSpec` — the frozen, hashable tile policy (``block_n``,
    ``block_k``, accumulator dtype, interpret flag) that every kernel wrapper
    takes instead of loose ints.  ``tile_shapes`` / ``update_tile_shapes``
    are the clamping+padding rules the kernels actually allocate with, and
    the ``*_vmem_bytes`` estimators price a candidate geometry *before*
    launching it — which is how the tuner (``kernels/tuning.py``) prunes its
    sweep grid.
  * :class:`DeviceProfile` — what the chip gives us: per-core VMEM and the
    double-buffering share the compiler claims for input DMA.  Looked up
    from ``jax.Device.device_kind`` with a conservative default for unknown
    chips (16 MiB / 1.33x == the historical 12 MiB budget, so CPU CI keeps
    its exact pre-profile behaviour).  ``REPRO_VMEM_BUDGET`` overrides the
    budget byte-for-byte for CI determinism and odd deployments.

The per-(device, dtype, shape) *winning* specs live in a JSON cache under
``experiments/tuning/`` — see ``kernels/tuning.py`` for the sweep and the
``tuned`` engine that consumes it.
"""
from __future__ import annotations

import dataclasses
import os
import warnings

F32 = 4                      # bytes per float32 — shared by every byte model
MiB = 2 ** 20

ENV_VMEM_BUDGET = "REPRO_VMEM_BUDGET"

_ACC_DTYPES = ("float32", "bfloat16")


# --------------------------------------------------------------- KernelSpec --

@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One kernel launch geometry.  Frozen and hashable: it is a jit static
    argument, a tuning-cache value, and a dict key — never mutate, ``replace``.

    ``acc_dtype`` is the on-chip compute dtype: tiles are cast to it before
    the MXU dots (``float32`` reproduces the historical kernels bit-for-bit;
    ``bfloat16`` halves the tile working set at reduced score precision —
    the cross-cluster argmin is usually insensitive, which is why the tuner
    may pick it).  Partial sums always accumulate into float32 outputs.

    ``interpret=None`` means "caller's policy" (``ops.py`` resolves it to
    compiled-on-TPU / interpreted-elsewhere); a concrete bool pins it.

    ``group_t`` is the batched-resident megakernel's subsets-per-grid-step
    group size (``kernels/batch_resident.py``); ``None`` means "fill the
    DeviceProfile VMEM budget" (``batched_group_size``).  Only the batched
    stack kernel reads it — per-subset kernels ignore it — and the tuner
    persists swept winners through it (cache keys carry an ``|m<bucket>``
    stack extension).
    """

    block_n: int = 256
    block_k: int = 128
    acc_dtype: str = "float32"
    interpret: bool | None = None
    group_t: int | None = None

    def __post_init__(self):
        for name in ("block_n", "block_k"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 8 or v % 8:
                raise ValueError(
                    f"{name}={v!r}: block sizes must be ints, >= 8 and "
                    f"sublane-aligned (multiples of 8)")
        if self.acc_dtype not in _ACC_DTYPES:
            raise ValueError(f"acc_dtype={self.acc_dtype!r}: "
                             f"expected one of {_ACC_DTYPES}")
        if self.group_t is not None and (
                not isinstance(self.group_t, int) or self.group_t < 1):
            raise ValueError(f"group_t={self.group_t!r}: group sizes must "
                             f"be ints >= 1 (or None for budget-derived)")

    def replace(self, **kw) -> "KernelSpec":
        return dataclasses.replace(self, **kw)

    def with_interpret(self, interpret: bool) -> "KernelSpec":
        if self.interpret == interpret:
            return self
        return dataclasses.replace(self, interpret=interpret)

    # ---- the tiling policy (single source of truth for every kernel) ----

    def tile_shapes(self, n: int, d: int, k: int):
        """(bn, bk, n_pad, k_pad, d_pad) for the (n x k)-gridded kernels
        (assign, fused): blocks clamp to the problem, n/k pad to block
        multiples, d zero-pads to the 128-lane boundary."""
        bn = min(self.block_n, max(8, n))
        bk = min(self.block_k, max(8, k))
        n_pad = -(-n // bn) * bn
        k_pad = -(-k // bk) * bk
        d_pad = max(-(-d // 128) * 128, 128)
        return bn, bk, n_pad, k_pad, d_pad

    def update_tile_shapes(self, n: int, d: int, k: int):
        """(bn, n_pad, k_pad, d_pad) for the n-gridded segment-sum kernel
        (centroid_update): no k blocking — the (k, d) output block stays
        resident — and k pads to 8 sublanes plus one trash row."""
        bn = min(self.block_n, max(8, n))
        n_pad = -(-n // bn) * bn
        d_pad = max(-(-d // 128) * 128, 128)
        k_pad = max(-(-(k + 1) // 8) * 8, 8)     # +1 trash row, padded points
        return bn, n_pad, k_pad, d_pad

    @property
    def acc_bytes(self) -> int:
        return 2 if self.acc_dtype == "bfloat16" else 4

    # ---- VMEM pricing (what the tuner prunes with) ----

    def assign_vmem_bytes(self, n: int, d: int, k: int) -> int:
        """Per-grid-step working set of the assign kernel: x/c/cn tiles in
        acc dtype + the f32 (best, idx) output pair."""
        bn, bk, _, _, d_pad = self.tile_shapes(n, d, k)
        return ((bn * d_pad + bk * d_pad + bk) * self.acc_bytes
                + 2 * bn * F32)

    def fused_vmem_bytes(self, n: int, d: int, k: int) -> int:
        """Per-grid-step working set of the fused kernel: input tiles in acc
        dtype + the VMEM-resident f32 (sums, counts, sse) output blocks and
        the (best, idx) argmin scratch."""
        bn, bk, _, k_pad, d_pad = self.tile_shapes(n, d, k)
        return ((bn * d_pad + bk * d_pad + bk + bn) * self.acc_bytes
                + (k_pad * d_pad + k_pad + 1 + 2 * bn) * F32)

    def assign_fused_vmem_bytes(self, n: int, d: int, k: int) -> int:
        """Per-grid-step working set of the fused kernel's assign-only mode
        (the serving hot path): the phase-1 x/c/cn tiles and argmin scratch
        only — no weights stream, no resident (k_pad, d_pad) sums/counts/sse
        output blocks — so the resident share drops from O(k_pad * d_pad)
        to the two (bn,) label/distance output tiles."""
        bn, bk, _, _, d_pad = self.tile_shapes(n, d, k)
        return ((bn * d_pad + bk * d_pad + bk) * self.acc_bytes
                + 4 * bn * F32)       # (best, idx) scratch + (labels, mind)

    def update_vmem_bytes(self, n: int, d: int, k: int) -> int:
        """Per-grid-step working set of the segment-sum kernel."""
        bn, _, k_pad, d_pad = self.update_tile_shapes(n, d, k)
        return ((bn * d_pad + 2 * bn + bn * k_pad) * self.acc_bytes
                + (k_pad * d_pad + k_pad) * F32)

    def init_vmem_bytes(self, n: int, d: int, c: int) -> int:
        """Per-grid-step working set of the k-means|| init-sweep kernel
        (``kernels/init.py``): x/candidate/norm tiles in acc dtype, plus the
        f32 streamed per-point vectors (old_mind, uniforms, weights), the
        (mind, sampled) output pair, the running-min scratch, and the
        resident (1, 1) potential.  The candidate set reuses the ``block_k``
        tiling axis."""
        bn, bc, _, _, d_pad = self.tile_shapes(n, d, c)
        return ((bn * d_pad + bc * d_pad + bc) * self.acc_bytes
                + (6 * bn + 1) * F32)

    # ---- cache (de)serialization ----

    def to_json(self) -> dict:
        out = {"block_n": self.block_n, "block_k": self.block_k,
               "acc_dtype": self.acc_dtype}
        if self.group_t is not None:       # absent = budget-derived, so old
            out["group_t"] = self.group_t  # caches stay schema-compatible
        return out

    @classmethod
    def from_json(cls, obj: dict) -> "KernelSpec":
        group_t = obj.get("group_t")
        return cls(block_n=int(obj["block_n"]), block_k=int(obj["block_k"]),
                   acc_dtype=str(obj.get("acc_dtype", "float32")),
                   group_t=None if group_t is None else int(group_t))


# module defaults — the historical per-kernel constants, now in ONE place
DEFAULT_SPEC = KernelSpec(block_n=256, block_k=128)
UPDATE_DEFAULT_SPEC = KernelSpec(block_n=512, block_k=128)


def coerce(spec: KernelSpec | None = None, *,
           block_n: int | None = None,
           block_k: int | None = None,
           interpret: bool | None = None,
           default: KernelSpec = DEFAULT_SPEC) -> KernelSpec:
    """Resolve a spec from the new-style ``spec=`` argument and/or the
    deprecated loose-int kwargs (the pre-spec kernel signatures).

    Passing ``block_n``/``block_k`` without a spec still works but warns:
    geometry should arrive as a :class:`KernelSpec` so the tuner's winners
    flow through unmodified.  Passing both is an error (ambiguous).
    """
    if spec is not None:
        if block_n is not None or block_k is not None:
            raise TypeError("pass either spec= or the deprecated "
                            "block_n=/block_k= ints, not both")
        out = spec
    elif block_n is not None or block_k is not None:
        warnings.warn(
            "loose block_n=/block_k= kwargs are deprecated; pass "
            "spec=KernelSpec(block_n=..., block_k=...) instead",
            DeprecationWarning, stacklevel=3)
        out = default.replace(**{f: v for f, v in
                                 (("block_n", block_n), ("block_k", block_k))
                                 if v is not None})
    else:
        out = default
    if interpret is not None:
        out = out.with_interpret(interpret)
    return out


# ------------------------------------------------------------ DeviceProfile --

@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """What the accelerator gives one kernel launch to work with.

    ``vmem_bytes`` is the per-core VMEM size; ``double_buffering`` is the
    multiplicative share the compiler claims for overlapped input DMA and
    spills, so the *usable* working-set budget is ``vmem_bytes /
    double_buffering``.  The feasibility guards (``resident_feasible``, the
    tuner's candidate pruning) budget against that, not the raw size.
    """

    device_kind: str
    vmem_bytes: int
    double_buffering: float = 4 / 3

    @property
    def budget_bytes(self) -> int:
        return int(self.vmem_bytes / self.double_buffering)

    def fits(self, nbytes: int) -> bool:
        return nbytes <= self.budget_bytes

    def resident_feasible(self, n: int, d: int, k: int,
                          prune: str = "none") -> bool:
        """Does a whole (n, d, k) Lloyd solve stay VMEM-resident here?
        ``prune="bounds"`` charges the bound-state bytes too."""
        from repro.kernels import resident           # deferred: no cycle
        return (resident.resident_vmem_bytes(n, d, k, prune=prune)
                <= self.budget_bytes)

    def max_resident_points(self, d: int, k: int,
                            prune: str = "none") -> int:
        """Largest n keeping a (d, k) solve resident — the S2 sizing knob."""
        from repro.kernels import resident
        return resident.max_resident_points(d, k, self.budget_bytes,
                                            prune=prune)

    def batched_group_size(self, m: int, s: int, d: int, k: int,
                           prune: str = "none") -> int:
        """Subsets per grid step that fill this chip's budget for an
        (M, S, d, k) reducer stack (0: even one subset does not fit) — the
        batched megakernel's group-sizing knob."""
        from repro.kernels import batch_resident
        return batch_resident.batched_group_size(m, s, d, k,
                                                 self.budget_bytes,
                                                 prune=prune)


# Approximate published per-core VMEM by device_kind (longest-prefix match on
# the lowercased jax.Device.device_kind).  Numbers are deliberately on the
# conservative side of public figures; where a deployment knows better,
# REPRO_VMEM_BUDGET overrides the budget outright.
DEVICE_PROFILES: dict[str, DeviceProfile] = {
    "tpu v2": DeviceProfile("tpu v2", 16 * MiB),
    "tpu v3": DeviceProfile("tpu v3", 16 * MiB),
    "tpu v4 lite": DeviceProfile("tpu v4 lite", 16 * MiB),
    "tpu v4": DeviceProfile("tpu v4", 32 * MiB),
    "tpu v5 lite": DeviceProfile("tpu v5 lite", 64 * MiB),
    "tpu v5p": DeviceProfile("tpu v5p", 64 * MiB),
    "tpu v6 lite": DeviceProfile("tpu v6 lite", 64 * MiB),
}

# Unknown chips (and CPU interpret-mode hosts) get 16 MiB / 1.33x == the 12
# MiB budget the resident engine historically hardcoded, so behaviour off
# real TPUs is unchanged by the profile layer.
DEFAULT_PROFILE = DeviceProfile("unknown", 16 * MiB)


def _local_device_kind() -> str:
    try:
        import jax
        return jax.devices()[0].device_kind
    except Exception:                                # no backend at all
        return "unknown"


def get_profile(device_kind: str | None = None) -> DeviceProfile:
    """Profile for ``device_kind`` (default: the local jax device), with the
    ``REPRO_VMEM_BUDGET`` env override applied.

    Matching is by longest lowercased prefix so e.g. ``"TPU v5 lite"`` hits
    the v5-lite row, not a bare ``"tpu v5"``; unknown kinds fall back to the
    conservative :data:`DEFAULT_PROFILE` (with the observed kind recorded,
    so logs show what failed to match).
    """
    kind = (_local_device_kind() if device_kind is None else device_kind)
    norm = kind.lower().strip()
    best = None
    for key, prof in DEVICE_PROFILES.items():
        if norm.startswith(key) and (best is None or len(key) > len(best[0])):
            best = (key, prof)
    profile = best[1] if best else dataclasses.replace(
        DEFAULT_PROFILE, device_kind=kind)
    env = os.environ.get(ENV_VMEM_BUDGET)
    if env:
        # override IS the budget: bytes usable, no double-buffering haircut
        profile = dataclasses.replace(profile, vmem_bytes=int(env),
                                      double_buffering=1.0)
    return profile


def vmem_budget_bytes(device_kind: str | None = None) -> int:
    """Usable VMEM working-set budget for the (local) device."""
    return get_profile(device_kind).budget_bytes
