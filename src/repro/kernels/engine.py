"""LloydEngine: the one place backend selection happens.

Every Lloyd backend is an engine registered in a name -> engine registry;
``core/kmeans.py``, ``core/pkmeans.py`` and the launch/benchmark drivers look
engines up by name instead of carrying ``if backend == ...`` chains.  The
protocol:

  * ``step(points, centroids, weights) -> (sums, counts, sse)`` — one Lloyd
    pass.  Mandatory; this is what PKMeans' per-iteration mapper calls.
  * ``assign(points, centroids) -> (labels, mind)`` — nearest-centroid
    labels, for callers that need the assignment itself (cluster dumps,
    reseeding).
  * ``sse(points, centroids, weights) -> ()`` — score a centroid set.
    Defaults to one ``step`` (so fused-style engines pay one sweep, not two).
  * ``update_minibatch(points, centroids, counts, weights) ->
    (centroids, counts, sse)`` — one Sculley-style mini-batch refresh: fold
    a sampled batch into the running centroids with per-center count-decayed
    learning rates (the ``ref.minibatch_merge`` closed form).  The base runs
    the jnp oracle; ``FusedEngine``+descendants override it to reuse the
    fused ``step`` sweep — one HBM pass per refresh batch, no label
    round-trip.  This is the serving tier's background refresh hook
    (``core/serve.py``).
  * ``solve(points, init, weights, max_iters, tol, reseed_empty, prune) ->
    (centroids, sse, iters, converged)`` — a whole solve.  The default drives
    ``step`` from a host-side ``lax.while_loop``; engines that own their
    convergence loop (``resident``) override it, which is how the loop moves
    from core/ down into the kernel layer.  ``prune`` ("none" | "bounds")
    selects the bound-gated block-skipping variant of the whole-solve
    kernels — a pure perf knob with a bit-for-bit-identical result, so
    per-step engines validate it and run their (always-exact) loop.
  * ``solve_batched(subsets, init, weights, max_iters, tol, reseed_empty,
    prune) -> (centroids (M,k,d), sse (M,), iters (M,), converged (M,))`` — a whole
    STACK of solves (one device's S2 reducer stack).  The default is a vmap
    of ``solve`` (so per-subset engines behave exactly as before — for
    ``resident`` that means a serialized grid of single-block kernels); the
    ``batched`` engine overrides it with the group-batched megakernel
    (``kernels/batch_resident.py``) so the stack becomes ONE pipelined
    launch.
  * ``resolve_spec(points, centroids) -> KernelSpec | None`` — the kernel-
    geometry hook.  EVERY engine's kernel launches route their block
    geometry through this method, so tuned geometry is one override away
    for any engine: the base returns ``None`` (each kernel's module
    default), the ``tuned`` engine (``kernels/tuning.py``) returns the
    autotuning cache's winner for the launch shape.

Engines registered: ``jnp`` | ``pallas`` | ``fused`` | ``resident`` |
``batched`` here, plus ``tuned`` from ``kernels/tuning.py`` — see
``kernels/__init__`` for when to pick each.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref

_REGISTRY: dict[str, "LloydEngine"] = {}


def register(engine: "LloydEngine") -> "LloydEngine":
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> "LloydEngine":
    if name not in _REGISTRY:
        raise ValueError(f"unknown backend: {name!r} "
                         f"(expected one of {tuple(_REGISTRY)})")
    return _REGISTRY[name]


def available() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def _as_weights(points, weights):
    return (jnp.ones(points.shape[0], jnp.float32) if weights is None
            else weights.astype(jnp.float32))


def reseed_empty_clusters(engine: "LloydEngine", points, weights,
                          centroids, counts):
    """Re-seed zero-count centroids at the farthest in-subset points.

    Bahmani et al.-style re-selection: a centroid no point maps to is a
    degenerate seed, so move it to the point farthest from the current
    centroid set (the k-means++ D^2 extreme).  The ``e``-th empty cluster
    takes the ``e``-th farthest point, so multiple empties land on distinct
    points.  The whole pass is gated behind ``lax.cond`` on any-empty —
    solves that never produce an empty cluster pay nothing (outside vmap).

    The selection itself (which rows to replace, with which points) is
    ``ref.reseed_farthest`` — the SAME function the resident and batched
    megakernels trace on-chip, so the in-kernel reseed matches this oracle
    bit-for-bit given the same score vector.  An empty cluster keeps its old
    centroid when there are fewer candidate points than empty clusters
    (subset smaller than k, or valid rows exhausted into ``-inf`` scores)
    rather than duplicating a pick or leaking padding coordinates.
    """
    k = centroids.shape[0]
    w = _as_weights(points, weights)
    empty = counts <= 0.0
    kk = min(k, points.shape[0])                       # candidate budget

    def do_reseed(c):
        _, mind = engine.assign(points, c)
        score = jnp.where(w > 0.0, mind.astype(jnp.float32), -jnp.inf)
        take, picks = ref.reseed_farthest(points, score, empty, kk)
        return jnp.where(take[:, None], picks.astype(c.dtype), c)

    return jax.lax.cond(jnp.any(empty), do_reseed, lambda c: c, centroids)


class LloydEngine:
    """Base engine: subclasses fill in ``step``/``assign``; ``solve`` and
    ``sse`` have default implementations built on them."""

    name: str = "?"

    def step(self, points, centroids, weights=None):
        """One Lloyd pass -> (sums (k,d) f32, counts (k,) f32, sse () f32)."""
        raise NotImplementedError

    def assign(self, points, centroids):
        """Nearest centroids -> (labels (n,) i32, min sq dists (n,) f32)."""
        raise NotImplementedError

    def resolve_spec(self, points, centroids):
        """Kernel geometry for this launch shape -> KernelSpec or None.

        ``None`` means "each kernel's module default" (``specs.DEFAULT_SPEC``
        and friends).  Runs at trace time on static shape/dtype info only, so
        overrides may do host-side work (cache lookups, table walks) freely.
        The ``tuned`` engine overrides this with the autotuning-cache lookup;
        pure-jnp engines never consult it.
        """
        return None

    def sse(self, points, centroids, weights=None):
        """Total weighted SSE of ``centroids`` over the subset.

        Default: one ``assign`` pass (the cheapest scoring an engine offers).
        Engines whose ``step`` already IS one sweep override this to reuse
        its sse output instead."""
        _, mind = self.assign(points, centroids)
        w = _as_weights(points, weights)
        return jnp.sum(w * mind)

    def update_minibatch(self, points, centroids, counts, weights=None):
        """One Sculley mini-batch refresh -> (centroids (k,d), counts (k,)
        f32, sse () f32).

        ``counts`` is the running per-center mass (from the full solve that
        produced ``centroids``, or accumulated across refreshes); it sets the
        learning rate ``1 / count`` and comes back grown by the batch.
        ``sse`` scores the batch against the *incoming* centroids (what was
        served when it arrived).  The base engine runs the jnp oracle; the
        returned centroids keep the input dtype like ``solve``."""
        new_c, new_counts, sse = ref.minibatch_update_ref(
            points, centroids, counts, weights)
        return new_c.astype(centroids.dtype), new_counts, sse

    def solve(self, points, init_centroids, weights=None, *,
              max_iters: int, tol: float, reseed_empty: bool = False,
              prune: str = "none"):
        """Lloyd to convergence -> (centroids, sse, iters, converged).

        The default host-side loop; ``max_iters``/``tol`` are static.
        ``prune`` is validated but otherwise ignored here: bound-gated
        skipping is an on-chip perf variant of the whole-solve kernels with
        a bit-for-bit-identical result (see kernels/resident.py), and the
        host-side per-step loop has no block state to skip — re-running the
        exact loop IS the pruned result.
        """
        # deferred import (like the lazy ops imports below): core imports
        # this module at its own import time.  ONE stop criterion everywhere
        # — pkmeans, the solve oracle and the resident kernel share it.
        from repro.core.metrics import centroid_shift
        from repro.kernels.resident import check_prune
        check_prune(prune)

        def cond(carry):
            c, it, shift = carry
            return jnp.logical_and(it < max_iters, shift > tol)

        def body(carry):
            c, it, _ = carry
            sums, counts, _ = self.step(points, c, weights)
            new_c = ref.divide_or_keep(sums, counts,
                                       c.astype(jnp.float32)).astype(c.dtype)
            if reseed_empty:
                new_c = reseed_empty_clusters(self, points, weights,
                                              new_c, counts)
            shift = centroid_shift(new_c.astype(jnp.float32),
                                   c.astype(jnp.float32))
            return new_c, it + 1, shift

        init = (init_centroids, jnp.int32(0), jnp.float32(jnp.inf))
        final_c, iters, shift = jax.lax.while_loop(cond, body, init)
        total = self.sse(points, final_c, weights)
        return final_c, total, iters, shift <= tol

    def solve_batched(self, subsets, init_centroids, weights=None, *,
                      max_iters: int, tol: float, reseed_empty: bool = False,
                      prune: str = "none"):
        """A stack of solves: (M,S,d),(k,d)[,(M,S)] ->
        (centroids (M,k,d), sse (M,), iters (M,) i32, converged (M,) bool).

        Default: vmap of ``solve`` over the stack — every per-subset engine
        composes under vmap unchanged (for ``resident`` this is the
        serialized grid of single-block kernels the ``batched`` engine
        replaces with one pipelined multi-group launch).  ``prune`` threads
        into each lane's solve (see ``solve``).
        """
        if weights is None:
            return jax.vmap(lambda p: self.solve(
                p, init_centroids, None, max_iters=max_iters, tol=tol,
                reseed_empty=reseed_empty, prune=prune))(subsets)
        return jax.vmap(lambda p, w: self.solve(
            p, init_centroids, w, max_iters=max_iters, tol=tol,
            reseed_empty=reseed_empty, prune=prune))(subsets, weights)


class JnpEngine(LloydEngine):
    """Pure-jnp reference — ground truth for every other engine, and the
    default on hosts without a TPU."""

    name = "jnp"

    def step(self, points, centroids, weights=None):
        return ref.lloyd_step_ref(points, centroids, weights)

    def assign(self, points, centroids):
        return ref.assign_ref(points, centroids)


class PallasEngine(LloydEngine):
    """Two-kernel Pallas path (assign, then centroid update): points stream
    HBM twice per iteration with an (n,) label/distance round-trip between —
    use it when the per-point labels themselves are the product."""

    name = "pallas"

    def step(self, points, centroids, weights=None):
        from repro.kernels import ops
        k = centroids.shape[0]
        w = _as_weights(points, weights)
        spec = self.resolve_spec(points, centroids)
        labels, mind = ops.assign(points, centroids, spec=spec)
        # the update kernel keeps its own (taller) default tile when the
        # hook declines; a concrete spec applies to both launches
        sums, counts = ops.centroid_update(points, labels, w, k, spec=spec)
        return sums, counts, jnp.sum(w * mind)

    def assign(self, points, centroids):
        from repro.kernels import ops
        return ops.assign(points, centroids,
                          spec=self.resolve_spec(points, centroids))


class FusedEngine(LloydEngine):
    """Single-pass fused kernel: one HBM sweep per iteration, labels never
    leave VMEM.  The preferred per-step TPU engine."""

    name = "fused"

    def step(self, points, centroids, weights=None):
        from repro.kernels import ops
        return ops.lloyd_step_fused(points, centroids, weights,
                                    spec=self.resolve_spec(points, centroids))

    def assign(self, points, centroids):
        # the fused kernel's optional labels output: still one sweep, no
        # second kernel and no (n,) HBM round-trip mid-pass
        from repro.kernels import ops
        return ops.lloyd_assign_fused(
            points, centroids, spec=self.resolve_spec(points, centroids))

    def sse(self, points, centroids, weights=None):
        # step IS one sweep here — its sse output is the cheapest scoring
        return self.step(points, centroids, weights)[2]

    def update_minibatch(self, points, centroids, counts, weights=None):
        # the fused sweep already produces exactly the (sums, bcounts, sse)
        # the Sculley merge consumes: one HBM pass per refresh batch, labels
        # never leave VMEM, then the shared closed form on (k,)-sized state
        sums, bcounts, sse = self.step(points, centroids, weights)
        new_c, new_counts = ref.minibatch_merge(centroids, counts,
                                                sums, bcounts)
        return new_c.astype(centroids.dtype), new_counts, sse


class ResidentEngine(FusedEngine):
    """VMEM-resident multi-iteration solver: ONE kernel launch runs the whole
    convergence loop on-chip, so the points stream from HBM once per *solve*
    instead of once per iteration.  Per-step behaviour (``step``/``assign``/
    ``sse``) is inherited from the fused engine; only the solve moves
    on-chip.  Empty-cluster reseeding runs *inside* the kernel (the shared
    ``ref.reseed_farthest`` selection, gated on any-empty per trip), so
    ``reseed_empty=True`` keeps the one-launch-per-solve property.  The only
    fallback to the fused per-step loop left is a genuinely infeasible
    shape: (n, d, k) exceeding the local chip's DeviceProfile VMEM budget
    (``resident_feasible``)."""

    name = "resident"

    def solve(self, points, init_centroids, weights=None, *,
              max_iters: int, tol: float, reseed_empty: bool = False,
              prune: str = "none"):
        from repro.kernels import ops, resident
        resident.check_prune(prune)
        n, d = points.shape
        k = init_centroids.shape[0]
        # the bound state is part of the working set, so a pruned solve can
        # be infeasible where the exact one fits — the guard knows
        if not resident.resident_feasible(n, d, k, prune=prune):
            return super().solve(points, init_centroids, weights,
                                 max_iters=max_iters, tol=tol,
                                 reseed_empty=reseed_empty)
        final_c, total, iters, conv = ops.lloyd_solve_resident(
            points, init_centroids, weights, max_iters=max_iters, tol=tol,
            reseed_empty=reseed_empty, prune=prune,
            spec=self.resolve_spec(points, init_centroids))
        return final_c.astype(init_centroids.dtype), total, iters, conv


class BatchedEngine(ResidentEngine):
    """Batched-resident megakernel for S2 reducer stacks: ONE pipelined
    ``pallas_call`` whose grid iterates over groups of T subsets, each grid
    step running its whole group's convergence loop on-chip with
    group-batched MXU matmuls while Pallas double-buffers the next group's
    points from HBM.  Per-stack launch count drops M -> ceil(M/T); per-
    subset semantics stay bit-for-bit the resident kernel's — including
    empty-cluster reseeding, which runs inside the group loop (per-lane
    masked argmax over the group's score matrix, the shared
    ``ref.reseed_farthest`` selection), so the paper-pipeline workloads that
    actually produce empty clusters keep the one-launch-per-stack property.
    Single solves (``solve``) inherit the resident path; only the stack
    moves into the megakernel.  The only fallback left (to vmap-of-solve,
    and from there to fused per-step loops) is a genuinely infeasible
    shape: even a T=1 group busting the DeviceProfile VMEM budget."""

    name = "batched"

    def resolve_group_size(self, m: int, s: int, d: int, k: int, dtype,
                           prune: str = "none"):
        """Subsets per grid step for an (M, S, d, k) stack — 0: infeasible.

        The tuning cache's ``group_t`` winner (keyed with the ``|m<bucket>``
        stack extension) takes precedence; otherwise fill the DeviceProfile
        budget via ``batched_group_size``.  Cached winners clamp to what the
        local budget actually affords, so a cache tuned on a bigger chip is
        always safe to consume.  ``prune`` charges the bound state to the
        budget-derived cap (and clamps cached winners the same way).
        """
        from repro.kernels import batch_resident
        from repro.kernels import tuning      # deferred: tuning imports us
        cap = batch_resident.batched_group_size(m, s, d, k, prune=prune)
        if cap <= 0:
            return 0
        cached = tuning.lookup_group_t(s, d, k, m, dtype)
        return min(cached, cap) if cached else cap

    def solve_batched(self, subsets, init_centroids, weights=None, *,
                      max_iters: int, tol: float, reseed_empty: bool = False,
                      prune: str = "none"):
        from repro.kernels import ops, resident
        resident.check_prune(prune)
        m, s, d = subsets.shape
        k = init_centroids.shape[0]
        # reseed_empty no longer gates the kernel: the tuning cache's
        # group_t winner resolves exactly as on the reseed-off path
        t = self.resolve_group_size(m, s, d, k, subsets.dtype, prune=prune)
        if t <= 0:
            return super().solve_batched(subsets, init_centroids, weights,
                                         max_iters=max_iters, tol=tol,
                                         reseed_empty=reseed_empty,
                                         prune=prune)
        final_c, sse, iters, conv = ops.lloyd_solve_batched(
            subsets, init_centroids, weights, group_t=t,
            max_iters=max_iters, tol=tol, reseed_empty=reseed_empty,
            prune=prune, spec=self.resolve_spec(subsets, init_centroids))
        return final_c.astype(init_centroids.dtype), sse, iters, conv


register(JnpEngine())
register(PallasEngine())
register(FusedEngine())
register(ResidentEngine())
register(BatchedEngine())
