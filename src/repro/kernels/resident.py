"""Pallas TPU kernel: VMEM-resident multi-iteration Lloyd solver.

The fused kernel (``fused.py``) collapsed one Lloyd iteration into one HBM
sweep — but a *solve* is many iterations, so the points still stream from HBM
once per iteration, which is the paper's job-per-iteration overhead transposed
onto the memory hierarchy.  For subsets that fit VMEM this kernel finishes the
argument: ONE ``pallas_call`` runs the entire convergence loop on-chip, so the
points cross the HBM boundary exactly once per *solve*.

TPU mapping (no grid — the whole subset is one block):

  * the ``(n, d)`` points tile, the ``(k, d)`` centroids and the ``(k, d)``
    sum / ``(k,)`` count accumulators all live in VMEM for the whole solve;
  * the convergence loop is a ``lax.while_loop`` *inside* the kernel; each
    trip is the same ``||c||^2 - 2 x.c`` MXU assignment + one-hot MXU
    segment-sum as the fused kernel, just without the HBM round-trip between
    iterations;
  * iteration/convergence state — the trip count and the ``shift > tol``
    predicate — is scalar state, carried through SMEM scratch
    (``pltpu.SMEM``), not vector registers;
  * after the loop, one extra on-chip assignment pass scores the converged
    centroids, matching the host solver's final-statistics pass;
  * with ``reseed_empty=True`` each trip re-seeds zero-count centroids at
    the farthest in-subset points without leaving the kernel: one extra
    masked score pass against the candidate centroids feeds the shared
    ``ref.reseed_farthest`` selection (the same function the host-side
    ``engine.reseed_empty_clusters`` oracle calls — bit-for-bit parity
    rests on shared code), gated behind ``lax.cond`` on any-empty so trips
    with every cluster populated pay nothing.  The reseed's score matrix
    reuses the assignment pass's working-set shape, so the VMEM byte model
    below is unchanged.

Padding follows the other kernels: d zero-padded to the 128-lane boundary
(exact for squared euclidean), n to the 8-sublane boundary, k to 8; padded
centroids are masked to +inf scores and keep-old semantics leaves their rows
fixed, so they contribute 0 to the shift; padded points carry weight 0.

Feasibility: the working set is ~``n*d + 2*n*k + 3*k*d`` floats (the (n, k)
score and one-hot matrices are materialized on-chip), so
:func:`resident_feasible` gates the launch and callers fall back to the
per-step fused engine when the subset does not fit — see
``kernels/engine.py``.  The budget it gates against is no longer a module
constant: it comes from the :class:`~repro.kernels.specs.DeviceProfile` of
the local chip (VMEM size / double-buffering share per ``device_kind``,
conservative 12 MiB default for unknown hosts, ``REPRO_VMEM_BUDGET`` env
override for CI determinism).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import specs
from repro.kernels.specs import F32, KernelSpec


DEFAULT_BOUND_BLOCK = 256   # target point-block rows for bound-gated pruning
BOUND_ITER_ROWS = 304       # nominal skip-counter rows in the VMEM byte model


def resident_tile_shapes(n: int, d: int, k: int):
    """Padded (n_pad, k_pad, d_pad) for the single-block resident kernel."""
    n_pad = -(-n // 8) * 8
    k_pad = -(-k // 8) * 8
    d_pad = max(-(-d // 128) * 128, 128)
    return n_pad, k_pad, d_pad


def bound_block_rows(n_pad: int, bound_block: int | None = None) -> int:
    """Pruning block size actually used for an ``n_pad``-row tile: the
    largest multiple-of-8 divisor of ``n_pad`` that is <= ``bound_block``
    (>= 8).  Dividing exactly keeps the pruned path's padded row count — and
    therefore its segment-sum reduction — IDENTICAL to the exact path's,
    which is half of the bit-for-bit parity argument."""
    if bound_block is None:
        bound_block = DEFAULT_BOUND_BLOCK
    q = n_pad // 8
    best = 8
    for f in range(1, q + 1):
        if q % f == 0 and 8 * f <= bound_block:
            best = 8 * f
    return best


def resident_vmem_bytes(n: int, d: int, k: int,
                        prune: str = "none") -> int:
    """f32 working-set bytes of one resident solve (everything on-chip).

    Counts the points tile, the (n, k) score + one-hot matrices, three (k, d)
    centroid-sized arrays (current, sums, new), and the (n,)/(k,) vectors
    (weights, ||x||^2, best, index, counts).  ``prune="bounds"`` adds the
    bound state the pruned loop carries: cached per-point assignments, the
    per-block margin/drift pair (worst case: 8-row blocks), and the
    skip-counter rows.
    """
    n_pad, k_pad, d_pad = resident_tile_shapes(n, d, k)
    total = (n_pad * d_pad                      # points
             + 2 * n_pad * k_pad                # scores + one-hot
             + 3 * k_pad * d_pad                # centroids, sums, new centroids
             + 4 * n_pad + 2 * k_pad) * F32     # w, x2, best, idx / counts, cn
    if prune == "bounds":
        total += (n_pad                         # cached assignments
                  + 2 * (n_pad // 8)            # margin + drift, 8-row blocks
                  + 2 * BOUND_ITER_ROWS) * F32  # skipped/total counters
    return total


def resident_feasible(n: int, d: int, k: int,
                      budget: int | None = None,
                      prune: str = "none") -> bool:
    """Can the whole solve stay resident in VMEM for this (n, d, k)?

    ``budget`` defaults to the local chip's :class:`DeviceProfile` working-
    set budget (``specs.get_profile().budget_bytes``) — the guard matches
    the hardware it runs on, not a hardcoded constant.  ``prune`` folds the
    bound-state bytes into the feasibility check.
    """
    if budget is None:
        budget = specs.get_profile().budget_bytes
    return resident_vmem_bytes(n, d, k, prune=prune) <= budget


def max_resident_points(d: int, k: int,
                        budget: int | None = None,
                        prune: str = "none") -> int:
    """Largest subset size n that keeps a (d, k) solve VMEM-resident.

    This is the sizing knob for IPKMeans S2: the paper's answer to a subset
    that does not fit is MORE reducers (larger M -> smaller subsets), so
    partition until ``subset_capacity(n) <= max_resident_points(d, k)`` and
    every reducer becomes a single kernel launch.
    """
    if budget is None:
        budget = specs.get_profile().budget_bytes
    _, k_pad, d_pad = resident_tile_shapes(8, d, k)
    fixed = (3 * k_pad * d_pad + 2 * k_pad) * F32
    per_n8 = 8 * (d_pad + 2 * k_pad + 4) * F32   # bytes per 8-row granule
    if prune == "bounds":
        fixed += 2 * BOUND_ITER_ROWS * F32
        per_n8 += (8 + 2) * F32                  # cached idx + margin/drift
    if fixed >= budget:
        return 0
    n = 8 * ((budget - fixed) // per_n8)
    return max(0, int(n))


def _resident_kernel(x_ref, c0_ref, w_ref,
                     c_out_ref, sse_ref, iters_ref, conv_ref, skips_ref,
                     state_scr, *,
                     k_actual: int, n_actual: int, max_iters: int,
                     tol: float, carry_dtype, reseed_empty: bool,
                     bound_block: int = 0):
    # deferred (trace-time) import: core imports the kernels package at its
    # own import time.  centroid_shift is pure jnp, so it traces on-chip —
    # the stop criterion has ONE definition across host loop/oracle/kernel.
    from repro.core.metrics import centroid_shift
    from repro.kernels.ref import (bound_gap, bound_second_best,
                                   bounds_may_skip, divide_or_keep,
                                   reseed_farthest)
    x = x_ref[...].astype(jnp.float32)                     # (n_pad, d_pad)
    w = w_ref[...].astype(jnp.float32)                     # (n_pad,)
    x2 = jnp.sum(x * x, axis=1)                            # (n_pad,)
    k_pad = c0_ref.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k_pad), 1)
    kk = min(k_actual, n_actual)                           # reseed candidates

    def score_points(c):
        """Masked per-point scores against a centroid set: (best, mind)."""
        cn = jnp.sum(c * c, axis=1)[None, :]               # (1, k_pad)
        s = cn - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32)
        s = jnp.where(col < k_actual, s, jnp.inf)          # mask padded centroids
        best = jnp.min(s, axis=1)
        mind = jnp.maximum(best + x2, 0.0)                 # row-constant restored
        return s, mind

    def assign_and_reduce(c):
        """One on-chip Lloyd pass -> (sums, counts, sse) — the fused kernel's
        phase 1 + phase 2, minus the HBM traffic."""
        s, mind = score_points(c)
        idx = jnp.argmin(s, axis=1).astype(jnp.int32)
        onehot = (idx[:, None] == col).astype(jnp.float32) * w[:, None]
        sums = jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
        counts = jnp.sum(onehot, axis=0)
        return sums, counts, jnp.sum(w * mind)

    def reseed(new_c, counts):
        """In-kernel farthest-point reseed of zero-count centroids: ONE extra
        masked assignment pass against the candidate centroids, then the
        shared ``reseed_farthest`` selection — the same score and the same
        selection the host-side ``engine.reseed_empty_clusters`` oracle
        computes, so the kernel path is bit-for-bit the old fallback's.
        Gated behind ``lax.cond`` on any-empty: trips with every cluster
        populated pay nothing."""
        empty = jnp.logical_and(counts <= 0.0, col[0] < k_actual)

        def do_reseed(c):
            _, mind = score_points(c)
            score = jnp.where(w > 0.0, mind, -jnp.inf)
            take, picks = reseed_farthest(x, score, empty, kk)
            # picks round-trip the carry dtype like every centroid update
            picks = picks.astype(carry_dtype).astype(jnp.float32)
            return jnp.where(take[:, None], picks, c)

        return jax.lax.cond(jnp.any(empty), do_reseed, lambda c: c, new_c)

    def update_centroids(c, idx):
        """Segment-sum + division from a full assignment vector.  ONE
        expression for the exact and pruned loops: the pruned path feeds
        cached assignments through the SAME contraction, so a skipped
        block's contribution is bitwise the contribution a fresh (provably
        identical) assignment would have made."""
        onehot = (idx[:, None] == col).astype(jnp.float32) * w[:, None]
        sums = jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
        counts = jnp.sum(onehot, axis=0)
        new_c = divide_or_keep(sums, counts, c)
        # the host loop carries centroids in the caller's dtype; round-trip
        # through it so feasible and fallback solves are bit-for-bit
        # consistent (identity for f32)
        new_c = new_c.astype(carry_dtype).astype(jnp.float32)
        if reseed_empty:
            new_c = reseed(new_c, counts)
        return new_c

    def cond(carry):
        c, it, shift = carry[:3]
        return jnp.logical_and(it < max_iters, shift > tol)

    def body(carry):
        c, it, _ = carry
        s, _ = score_points(c)
        idx = jnp.argmin(s, axis=1).astype(jnp.int32)
        new_c = update_centroids(c, idx)
        shift = centroid_shift(new_c, c)
        # scalar loop state lives in SMEM: trip count + converged predicate
        state_scr[0] = it + 1
        state_scr[1] = jnp.where(shift <= tol, 1, 0)
        return new_c, it + 1, shift

    n_pad = x.shape[0]
    iters_rows = skips_ref.shape[0]
    c0 = c0_ref[...].astype(jnp.float32)
    state_scr[0] = 0                                       # iterations executed
    state_scr[1] = 0                                       # converged flag

    if not bound_block:
        final_c, _, _ = jax.lax.while_loop(
            cond, body, (c0, jnp.int32(0), jnp.float32(jnp.inf)))
        skips_ref[...] = jnp.zeros((iters_rows, 2), jnp.int32)
    else:
        # ---- bound-gated block skipping (prune="bounds") ----
        # Extra carried state: cached per-point assignments, per-block
        # reassignment margin (worst-case d2 - d1 at the last scored trip),
        # per-block drift accumulated since, and the skip counters.  Each
        # trip re-scores only the blocks the triangle inequality cannot
        # clear (ref.bounds_may_skip); skipped blocks reuse their cached
        # assignments, and the centroid update is the SAME full segment-sum
        # either way — which is why pruned == exact bit for bit.
        bb = bound_block
        nb = n_pad // bb
        colb = col[:bb]                                    # (bb, k_pad)

        def score_blocks(c, idx, margin, skip_b):
            """Re-score the non-skippable blocks; cached blocks pass
            through untouched behind ``lax.cond`` (a real branch — no grid,
            no vmap — so a skipped block costs no MXU work)."""
            cn = jnp.sum(c * c, axis=1)[None, :]

            def blk(b, carry):
                def compute(args):
                    idx, margin = args
                    xb = jax.lax.dynamic_slice_in_dim(x, b * bb, bb, 0)
                    x2b = jax.lax.dynamic_slice_in_dim(x2, b * bb, bb, 0)
                    wb = jax.lax.dynamic_slice_in_dim(w, b * bb, bb, 0)
                    s = cn - 2.0 * jnp.dot(xb, c.T,
                                           preferred_element_type=jnp.float32)
                    s = jnp.where(colb < k_actual, s, jnp.inf)
                    ib = jnp.argmin(s, axis=1).astype(jnp.int32)
                    gap = bound_gap(jnp.min(s, axis=1) + x2b,
                                    bound_second_best(s, ib) + x2b,
                                    wb > 0.0)
                    idx = jax.lax.dynamic_update_slice_in_dim(
                        idx, ib, b * bb, 0)
                    margin = jax.lax.dynamic_update_slice_in_dim(
                        margin, jnp.min(gap)[None], b, 0)
                    return idx, margin

                return jax.lax.cond(skip_b[b], lambda a: a, compute, carry)

            return jax.lax.fori_loop(0, nb, blk, (idx, margin))

        def body_pruned(carry):
            c, it, _, idx, margin, dacc, skips = carry
            skip_b = bounds_may_skip(margin, dacc)         # (nb,)
            idx, margin = score_blocks(c, idx, margin, skip_b)
            new_c = update_centroids(c, idx)
            shift = centroid_shift(new_c, c)
            # a scored block's drift restarts at this trip's shift; a
            # skipped block keeps accumulating against its stored margin
            dacc = jnp.where(skip_b, dacc + shift, shift)
            skips = skips.at[it, 0].set(jnp.sum(skip_b.astype(jnp.int32)))
            skips = skips.at[it, 1].set(nb)
            state_scr[0] = it + 1
            state_scr[1] = jnp.where(shift <= tol, 1, 0)
            return new_c, it + 1, shift, idx, margin, dacc, skips

        init = (c0, jnp.int32(0), jnp.float32(jnp.inf),
                jnp.zeros((n_pad,), jnp.int32),
                jnp.full((nb,), -jnp.inf, jnp.float32),   # never skip pass 1
                jnp.zeros((nb,), jnp.float32),
                jnp.zeros((iters_rows, 2), jnp.int32))
        final_c, _, _, _, _, _, skips = jax.lax.while_loop(
            cond, body_pruned, init)
        skips_ref[...] = skips

    # final statistics with the converged centroids (host solvers do the same
    # extra assignment pass — here it never leaves VMEM)
    _, _, final_sse = assign_and_reduce(final_c)
    c_out_ref[...] = final_c
    sse_ref[0, 0] = final_sse
    iters_ref[0, 0] = state_scr[0]
    conv_ref[0, 0] = state_scr[1]


def check_prune(prune: str) -> str:
    """Validate a ``prune`` mode string (shared by every layer that takes
    one).  Returns the value so callers can inline it."""
    if prune not in ("none", "bounds"):
        raise ValueError(
            f"unknown prune mode {prune!r} (expected 'none' or 'bounds')")
    return prune


@functools.partial(jax.jit,
                   static_argnames=("max_iters", "tol", "interpret",
                                    "reseed_empty", "prune", "bound_block"))
def _lloyd_solve_resident(points: jnp.ndarray,
                          centroids: jnp.ndarray,
                          weights: jnp.ndarray | None = None,
                          *,
                          max_iters: int = 300,
                          tol: float = 1e-6,
                          interpret: bool = False,
                          reseed_empty: bool = False,
                          prune: str = "none",
                          bound_block: int | None = None):
    n, d = points.shape
    k = centroids.shape[0]
    n_pad, k_pad, d_pad = resident_tile_shapes(n, d, k)
    bb = bound_block_rows(n_pad, bound_block) if prune == "bounds" else 0
    iters_rows = max(int(max_iters), 1)

    x = jnp.zeros((n_pad, d_pad), points.dtype).at[:n, :d].set(points)
    c = jnp.zeros((k_pad, d_pad), centroids.dtype).at[:k, :d].set(centroids)
    w = jnp.zeros((n_pad,), jnp.float32)
    w = w.at[:n].set(1.0 if weights is None else weights.astype(jnp.float32))

    c_out, sse, iters, conv, skips = pl.pallas_call(
        functools.partial(_resident_kernel, k_actual=k, n_actual=n,
                          max_iters=max_iters, tol=tol,
                          carry_dtype=centroids.dtype,
                          reseed_empty=reseed_empty, bound_block=bb),
        out_shape=[
            jax.ShapeDtypeStruct((k_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((iters_rows, 2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((2,), jnp.int32),          # (trip count, converged)
        ],
        interpret=interpret,
    )(x, c, w)

    return (c_out[:k, :d].astype(centroids.dtype), sse[0, 0],
            iters[0, 0], conv[0, 0].astype(bool), skips)


def lloyd_solve_resident(points: jnp.ndarray,
                         centroids: jnp.ndarray,
                         weights: jnp.ndarray | None = None,
                         *,
                         max_iters: int = 300,
                         tol: float = 1e-6,
                         interpret: bool | None = None,
                         spec: KernelSpec | None = None,
                         reseed_empty: bool = False,
                         prune: str = "none",
                         bound_block: int | None = None,
                         return_skips: bool = False):
    """Full Lloyd solve in ONE kernel launch: (n,d),(k,d)[,(n,)] ->
    (centroids (k,d), sse (), iters () i32, converged () bool).

    Semantics match ``core.kmeans``'s host loop exactly: iterate while
    ``iters < max_iters and shift > tol`` with keep-old-centroid handling of
    empty clusters, then score the final centroids.  With
    ``reseed_empty=True`` each trip additionally re-seeds zero-count
    centroids at the farthest in-subset points *on-chip* (the shared
    ``ref.reseed_farthest`` selection over one extra masked assignment pass,
    gated on any-empty), matching the host-side
    ``engine.reseed_empty_clusters`` oracle — the solve stays one launch.
    Callers MUST check :func:`resident_feasible` first — the engine layer
    does, and falls back to the per-step fused path when the subset does not
    fit VMEM.

    ``prune="bounds"`` turns on Hamerly-style bound-gated block skipping
    inside the on-chip loop: blocks of ``bound_block`` points (rounded to a
    divisor of the padded tile; default ``DEFAULT_BOUND_BLOCK``) whose
    stored reassignment margin exceeds twice the accumulated centroid drift
    skip their score pass and reuse cached assignments.  The result is
    bit-for-bit the exact solve's (see ``ref.lloyd_solve_bounds_ref``).
    ``return_skips=True`` appends a ``(max_iters, 2)`` int32 counter —
    [blocks skipped, blocks total] per iteration, zero rows past
    convergence (and everywhere for ``prune="none"``).

    This kernel has no block geometry (the whole subset is one block), so of
    a :class:`KernelSpec` only the interpret flag applies; on-chip arithmetic
    is fixed f32 because the carry-dtype round-trip defines the fallback
    parity contract.
    """
    check_prune(prune)
    if interpret is None:
        interpret = (spec.interpret if spec is not None
                     and spec.interpret is not None else False)
    out = _lloyd_solve_resident(points, centroids, weights,
                                max_iters=max_iters, tol=tol,
                                interpret=bool(interpret),
                                reseed_empty=bool(reseed_empty),
                                prune=prune,
                                bound_block=bound_block)
    return out if return_skips else out[:4]
