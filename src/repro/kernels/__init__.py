"""Pallas TPU kernels for the k-means hot-spots (assignment + update)."""
from repro.kernels import ops, ref
from repro.kernels.assign import assign_pallas
from repro.kernels.centroid_update import centroid_update_pallas

__all__ = ["ops", "ref", "assign_pallas", "centroid_update_pallas"]
