"""Pallas TPU kernels for the k-means hot-spots.

Backends (selected via ``KMeansParams.backend`` / ``IPKMeansConfig``):

  * ``jnp``    — pure-jnp reference (``ref.py``).  Ground truth for every
    kernel test, and the default on hosts without a TPU where wall-clock of
    the interpreted kernels is meaningless.  Use it for debugging and as the
    oracle in CI.
  * ``pallas`` — the two-kernel path: ``assign.py`` (online min/argmin over
    centroid tiles) then ``centroid_update.py`` (MXU one-hot segment-sum).
    Streams all ``n`` points from HBM twice per Lloyd iteration and
    round-trips the ``(n,)`` labels/distances through HBM in between.  Use
    it when the labels themselves are needed (e.g. final assignment dumps).
  * ``fused``  — ``fused.py``: one grid sweep does assignment *and*
    accumulates per-cluster sums/counts/SSE, so points are read once per
    iteration and labels never leave VMEM (~half the HBM traffic of
    ``pallas``).  The preferred TPU backend for the Lloyd inner loop.

CI exercises all three: the kernel-correctness job sweeps ``pallas`` and
``fused`` in interpret mode against ``ref.py`` (tests/test_kernels.py,
tests/test_fused.py), and the tier-1 gate runs the solvers on the ``jnp``
backend.  On non-TPU hosts ``ops.py`` transparently falls back to
``interpret=True``.
"""
from repro.kernels import ops, ref
from repro.kernels.assign import assign_pallas
from repro.kernels.centroid_update import centroid_update_pallas
from repro.kernels.fused import lloyd_step_fused

__all__ = ["ops", "ref", "assign_pallas", "centroid_update_pallas",
           "lloyd_step_fused"]
