"""Pallas TPU kernels for the k-means hot-spots, behind the LloydEngine
registry — with kernel geometry owned by one subsystem.

**Geometry** (``specs.py`` / ``tuning.py``): every kernel launch takes a
frozen :class:`~repro.kernels.specs.KernelSpec` (block_n, block_k, on-chip
acc dtype, interpret flag) instead of loose ints; the module defaults live
in ``specs.py`` — no kernel file carries its own block constants.  What the
chip affords is a :class:`~repro.kernels.specs.DeviceProfile` (per-core VMEM
x double-buffering share, looked up from ``jax.Device.device_kind``, env
override ``REPRO_VMEM_BUDGET``): the resident engine's feasibility guard and
the tuner's candidate pruning both budget against it.  Specs reach kernels
through the engine protocol's ``resolve_spec(points, centroids)`` hook — the
base returns ``None`` (defaults); the ``tuned`` engine returns the winner
recorded by the offline sweep (``python -m repro.launch.autotune``) in the
JSON cache under ``experiments/tuning/``.

**Engines** (``engine.py``; ``KMeansParams.backend`` /
``IPKMeansConfig.with_backend`` pick one by name):

  * ``jnp``      — pure-jnp reference (``ref.py``).  Ground truth for every
    kernel test, and the default on hosts without a TPU where wall-clock of
    the interpreted kernels is meaningless.  Use it for debugging and as the
    oracle in CI.
  * ``pallas``   — the two-kernel path: ``assign.py`` (online min/argmin over
    centroid tiles) then ``centroid_update.py`` (MXU one-hot segment-sum).
    Streams all ``n`` points from HBM twice per Lloyd iteration and
    round-trips the ``(n,)`` labels/distances through HBM in between.  Use
    it when the per-point labels are the product of every iteration.
  * ``fused``    — ``fused.py``: one grid sweep does assignment *and*
    accumulates per-cluster sums/counts/SSE, so points are read once per
    iteration and labels never leave VMEM (~half the HBM traffic of
    ``pallas``); an optional final-pass labels output serves cluster dumps
    without a second kernel.  The preferred per-step TPU engine, and the
    fallback for ``resident``.
  * ``resident`` — ``resident.py``: the whole convergence loop in ONE kernel
    launch.  Centroids and the (k, d) accumulators stay resident in VMEM,
    iteration/convergence state sits in SMEM, and the points stream from HBM
    once per *solve* instead of once per iteration — the paper's
    one-job-instead-of-one-job-per-iteration argument finished at the memory
    hierarchy.  Gated by the DeviceProfile VMEM-feasibility check with
    automatic fallback to ``fused`` when (n, d, k) does not fit on-chip.
  * ``tuned``    — ``tuning.py``: ``resident`` solve semantics + autotuned
    kernel geometry.  Its ``resolve_spec`` hook serves the cached
    per-(device, dtype, shape) winner, falling back to the defaults on a
    cache miss, so it is always safe to request.  The preferred TPU engine
    for the IPKMeans S2 reducers once the target shapes have been swept.

CI exercises all of them: the kernel-correctness job sweeps ``pallas``,
``fused``, ``resident`` and ``tuned`` in interpret mode against the oracles
in ``ref.py`` (tests/test_kernels.py, tests/test_fused.py,
tests/test_engines.py, tests/test_tuning.py — the last covers the cache
round-trip, spec clamping, and tuned-vs-oracle parity), and an autotune
smoke job runs a tiny sweep end to end and re-reads the cache it wrote.  On
non-TPU hosts ``ops.py`` transparently falls back to ``interpret=True``.
"""
from repro.kernels import engine, ops, ref, specs, tuning
from repro.kernels.assign import assign_pallas
from repro.kernels.centroid_update import centroid_update_pallas
from repro.kernels.engine import LloydEngine, available, get_engine, register
from repro.kernels.fused import lloyd_step_fused
from repro.kernels.resident import (lloyd_solve_resident, resident_feasible,
                                    resident_vmem_bytes)
from repro.kernels.specs import DeviceProfile, KernelSpec, get_profile
from repro.kernels.tuning import TuningCache, autotune_step, lookup_spec

__all__ = ["engine", "ops", "ref", "specs", "tuning",
           "assign_pallas", "centroid_update_pallas",
           "lloyd_step_fused", "lloyd_solve_resident", "resident_feasible",
           "resident_vmem_bytes", "LloydEngine", "available", "get_engine",
           "register", "DeviceProfile", "KernelSpec", "get_profile",
           "TuningCache", "autotune_step", "lookup_spec"]
