"""Pallas TPU kernels for the k-means hot-spots, behind the LloydEngine
registry — with kernel geometry owned by one subsystem.

**Geometry** (``specs.py`` / ``tuning.py``): every kernel launch takes a
frozen :class:`~repro.kernels.specs.KernelSpec` (block_n, block_k, on-chip
acc dtype, interpret flag, batched group size) instead of loose ints; the
module defaults live in ``specs.py`` — no kernel file carries its own block
constants.  What the chip affords is a :class:`~repro.kernels.specs
.DeviceProfile` (per-core VMEM x double-buffering share, looked up from
``jax.Device.device_kind``, env override ``REPRO_VMEM_BUDGET``): the
resident engine's feasibility guard, the batched engine's group sizing and
the tuner's candidate pruning all budget against it.  Specs reach kernels
through the engine protocol's ``resolve_spec(points, centroids)`` hook — the
base returns ``None`` (defaults); the ``tuned`` engine returns the winner
recorded by the offline sweep (``python -m repro.launch.autotune``) in the
JSON cache under ``experiments/tuning/``.

**Engines** (``engine.py``; ``KMeansParams.backend`` /
``IPKMeansConfig.with_backend`` pick one by name):

  * ``jnp``      — pure-jnp reference (``ref.py``).  Ground truth for every
    kernel test, and the default on hosts without a TPU where wall-clock of
    the interpreted kernels is meaningless.  Use it for debugging and as the
    oracle in CI.
  * ``pallas``   — the two-kernel path: ``assign.py`` (online min/argmin over
    centroid tiles) then ``centroid_update.py`` (MXU one-hot segment-sum).
    Streams all ``n`` points from HBM twice per Lloyd iteration and
    round-trips the ``(n,)`` labels/distances through HBM in between.  Use
    it when the per-point labels are the product of every iteration.
  * ``fused``    — ``fused.py``: one grid sweep does assignment *and*
    accumulates per-cluster sums/counts/SSE, so points are read once per
    iteration and labels never leave VMEM (~half the HBM traffic of
    ``pallas``); an optional final-pass labels output serves cluster dumps
    without a second kernel.  The preferred per-step TPU engine, and the
    ultimate fallback for the whole-solve engines.
  * ``resident`` — ``resident.py``: ONE subset's whole convergence loop in
    one kernel launch.  Centroids and the (k, d) accumulators stay resident
    in VMEM, iteration/convergence state sits in SMEM, and the points stream
    from HBM once per *solve* instead of once per iteration.  Gated by the
    DeviceProfile VMEM-feasibility check with automatic fallback to
    ``fused`` when (n, d, k) does not fit on-chip — the ONLY remaining
    fallback trigger: empty-cluster reseeding (``reseed_empty=True``) runs
    *inside* the kernel loop (one extra masked score pass + the shared
    ``ref.reseed_farthest`` selection, gated on any-empty), so the paper's
    quality configuration keeps the one-launch-per-solve property.  Under
    vmap (a reducer stack) it serializes: one single-block grid step per
    subset, no overlap.
  * ``batched``  — ``batch_resident.py``: a whole reducer STACK in one
    pipelined launch.  The grid iterates over groups of T subsets; each
    grid step runs its group's convergence loop on-chip with group-batched
    MXU matmuls (``dot_general`` batch dim over the group) while Pallas
    double-buffers the next group's points from HBM — per-stack launches
    drop M -> ceil(M/T) and the HBM stream overlaps compute.  T fills the
    DeviceProfile budget (``batched_group_size``) or comes from the tuning
    cache's ``group_t`` winner — consulted for reseed-on stacks too.
    Per-subset semantics are bit-for-bit the resident kernel's, including
    the in-kernel per-lane farthest-point reseed; single solves inherit the
    resident path.  The preferred S2 stack engine on TPU, and since the
    paper pipeline only matches PKMeans quality with ``reseed_empty=True``,
    the reseed-on stack IS the hot path it serves.
  * ``tuned``    — ``tuning.py``: ``resident`` solve semantics + autotuned
    kernel geometry.  Its ``resolve_spec`` hook serves the cached
    per-(device, dtype, shape) winner, falling back to the defaults on a
    cache miss, so it is always safe to request — with or without
    ``reseed_empty`` (the flag no longer drops it off the kernel or past
    the cache lookup).

The engine protocol's ``solve_batched`` hook is where stacks enter: the base
is a vmap of ``solve`` (every per-subset engine composes unchanged), and
``batched`` overrides it with the megakernel — ``core.kmeans.kmeans_batched``
delegates whole stacks there, so the choice is one backend string away for
``ipkmeans`` / ``ipkmeans_distributed`` / ``kmeans_dryrun`` alike.

**Initialization** (``init.py``; ``KMeansParams.init`` /
``IPKMeansConfig.with_init``): seeding is not a Lloyd engine but rides the
same machinery — the k-means|| oversampled init (Bahmani et al.) runs each
of its O(log n) rounds as ONE fused distance+min+sample sweep
(``ops.init_sweep``, KernelSpec-tiled like ``fused.py``, jnp oracle
``ref.init_sweep_ref``, VMEM pricing ``KernelSpec.init_vmem_bytes``, tuner
``tuning.autotune_init_sweep`` under ``|init`` cache keys), with the round
loop and the weighted k-means++ recluster on host
(``core.init.kmeans_parallel_init``).  Better seeds cut Lloyd
iterations-to-converge — fewer on-chip while-loop trips per
resident/batched launch.

**Pruning** (``KMeansParams.prune`` / ``IPKMeansConfig.with_prune``;
``'none' | 'bounds'``): with ``'bounds'``, the whole-solve kernels
(``resident`` / ``batched`` / ``tuned``) carry a Hamerly-style bound per
point block — the block's smallest best-vs-second-best distance margin —
plus the accumulated max centroid drift since that block was last scored,
and wrap each block's score matmul in a ``lax.cond`` that skips it when the
triangle inequality proves no assignment in the block can change.  Skipped
blocks reuse their cached labels in the SAME full segment-sum contraction
the exact path runs, so results are bit-for-bit identical — pruning is a
pure perf knob (see docs/kernels.md for the state layout and the proof
obligation; ``ref.lloyd_solve_bounds_ref`` is the jnp oracle, and the
kernels' ``return_skips=True`` exposes per-iteration [skipped, total] block
counters that ``benchmarks/kernel_bench.py`` snapshots).  Host-loop engines
validate and ignore the flag: their exact per-step loop already IS the
pruned result.

CI exercises all of them: the kernel-correctness job sweeps ``pallas``,
``fused``, ``resident``, ``batched`` and ``tuned`` in interpret mode against
the oracles in ``ref.py`` (tests/test_kernels.py, tests/test_fused.py,
tests/test_engines.py, tests/test_tuning.py, tests/test_batched.py — the
last covers stack-vs-vmap-oracle parity incl. heterogeneous convergence and
the single-``pallas_call`` lowering guarantee with reseeding on and off —
plus tests/test_reseed.py: in-kernel reseed vs the host-side
``reseed_empty_clusters`` oracle, bit-for-bit, and tests/test_prune.py:
pruned-vs-exact bitwise parity across engines/dtypes/paddings plus a
directed nonzero-late-skip check), and an autotune smoke job
runs a tiny sweep — including the ``--group-ts`` group-size axis through
the reseed-on megakernel (``--reseed-empty``) — end to end and re-reads the
cache it wrote.  On non-TPU hosts ``ops.py`` transparently falls back to
``interpret=True``.
"""
from repro.kernels import batch_resident, engine, init, ops, ref, specs, tuning
from repro.kernels.assign import assign_pallas
from repro.kernels.batch_resident import (batched_feasible,
                                          batched_group_size,
                                          lloyd_solve_batched)
from repro.kernels.centroid_update import centroid_update_pallas
from repro.kernels.engine import LloydEngine, available, get_engine, register
from repro.kernels.fused import lloyd_step_fused
from repro.kernels.init import init_sweep
from repro.kernels.resident import (check_prune, lloyd_solve_resident,
                                    resident_feasible, resident_vmem_bytes)
from repro.kernels.specs import DeviceProfile, KernelSpec, get_profile
from repro.kernels.tuning import (TuningCache, autotune_init_sweep,
                                  autotune_step, lookup_init_spec,
                                  lookup_spec)

__all__ = ["batch_resident", "engine", "init", "ops", "ref", "specs",
           "tuning", "assign_pallas", "centroid_update_pallas",
           "batched_feasible", "batched_group_size", "lloyd_solve_batched",
           "lloyd_step_fused", "lloyd_solve_resident", "resident_feasible",
           "resident_vmem_bytes", "check_prune", "init_sweep",
           "LloydEngine", "available", "get_engine",
           "register", "DeviceProfile", "KernelSpec", "get_profile",
           "TuningCache", "autotune_step", "autotune_init_sweep",
           "lookup_spec", "lookup_init_spec"]
