"""Pallas TPU kernels for the k-means hot-spots, behind the LloydEngine
registry.

Backend selection is no longer string-dispatch scattered across core/ — every
backend is a :class:`~repro.kernels.engine.LloydEngine` registered by name in
``engine.py``; ``KMeansParams.backend`` / ``IPKMeansConfig.with_backend`` pick
one and the solvers call ``engine.step`` / ``engine.solve``:

  * ``jnp``      — pure-jnp reference (``ref.py``).  Ground truth for every
    kernel test, and the default on hosts without a TPU where wall-clock of
    the interpreted kernels is meaningless.  Use it for debugging and as the
    oracle in CI.
  * ``pallas``   — the two-kernel path: ``assign.py`` (online min/argmin over
    centroid tiles) then ``centroid_update.py`` (MXU one-hot segment-sum).
    Streams all ``n`` points from HBM twice per Lloyd iteration and
    round-trips the ``(n,)`` labels/distances through HBM in between.  Use
    it when the per-point labels are the product of every iteration.
  * ``fused``    — ``fused.py``: one grid sweep does assignment *and*
    accumulates per-cluster sums/counts/SSE, so points are read once per
    iteration and labels never leave VMEM (~half the HBM traffic of
    ``pallas``); an optional final-pass labels output serves cluster dumps
    without a second kernel.  The preferred per-step TPU engine, and the
    fallback for ``resident``.
  * ``resident`` — ``resident.py``: the whole convergence loop in ONE kernel
    launch.  Centroids and the (k, d) accumulators stay resident in VMEM,
    iteration/convergence state sits in SMEM, and the points stream from HBM
    once per *solve* instead of once per iteration — the paper's
    one-job-instead-of-one-job-per-iteration argument finished at the memory
    hierarchy.  Only engine that overrides ``engine.solve``; gated by a
    VMEM-feasibility check with automatic fallback to ``fused`` when
    (n, d, k) does not fit on-chip.  The preferred TPU engine for the
    IPKMeans S2 reducers, whose subsets are sized to fit.

CI exercises all four: the kernel-correctness job sweeps ``pallas``,
``fused`` and ``resident`` in interpret mode against the oracles in
``ref.py`` (tests/test_kernels.py, tests/test_fused.py, tests/test_engines.py
— the last adds a hypothesis property test that all registered engines agree
on (sums, counts, sse)), and the tier-1 gate runs the solvers on the ``jnp``
engine.  On non-TPU hosts ``ops.py`` transparently falls back to
``interpret=True``.
"""
from repro.kernels import engine, ops, ref
from repro.kernels.assign import assign_pallas
from repro.kernels.centroid_update import centroid_update_pallas
from repro.kernels.engine import LloydEngine, available, get_engine, register
from repro.kernels.fused import lloyd_step_fused
from repro.kernels.resident import (lloyd_solve_resident, resident_feasible,
                                    resident_vmem_bytes)

__all__ = ["engine", "ops", "ref", "assign_pallas", "centroid_update_pallas",
           "lloyd_step_fused", "lloyd_solve_resident", "resident_feasible",
           "resident_vmem_bytes", "LloydEngine", "available", "get_engine",
           "register"]
