"""Pallas TPU kernel: tiled nearest-centroid assignment.

The assignment step is the FLOP hot-spot every IPKMeans reducer executes
(n*k*d MACs per Lloyd iteration).  TPU mapping:

  * the ``-2 x.cT`` term is a (bn x d) @ (d x bk) matmul on the MXU
    (``preferred_element_type=f32`` accumulation);
  * grid = (n_blocks, k_blocks) with k minor: each x-tile stays resident in
    VMEM while centroid tiles stream past it, carrying a running
    (best_score, best_index) pair in the revisited output block — a flash-
    attention-style online reduction, so the (n x k) distance matrix is never
    materialized in HBM;
  * d is zero-padded to the 128-lane boundary (exact for squared-euclidean),
    n and k are padded to block multiples with +inf masking on k.

``x-norm`` is row-constant so it cannot change the argmin; the kernel reduces
``||c||^2 - 2 x.c`` and the wrapper adds ``||x||^2`` back for the distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, cn_ref, best_ref, idx_ref, *,
                   block_k: int, k_actual: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)                    # (bn, d)
    c = c_ref[...].astype(jnp.float32)                    # (bk, d)
    cn = cn_ref[...].astype(jnp.float32)                  # (1, bk)

    # score = ||c||^2 - 2 x.c   (row-constant ||x||^2 omitted)
    s = cn - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < k_actual, s, jnp.inf)             # mask padded centroids

    local_best = jnp.min(s, axis=1)                       # (bn,)
    local_idx = (jnp.argmin(s, axis=1).astype(jnp.int32) + j * block_k)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = local_best
        idx_ref[...] = local_idx

    @pl.when(j > 0)
    def _accumulate():
        prev_best = best_ref[...]
        prev_idx = idx_ref[...]
        take = local_best < prev_best                     # strict: low-index ties win
        best_ref[...] = jnp.where(take, local_best, prev_best)
        idx_ref[...] = jnp.where(take, local_idx, prev_idx)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def assign_pallas(points: jnp.ndarray,
                  centroids: jnp.ndarray,
                  *,
                  block_n: int = 256,
                  block_k: int = 128,
                  interpret: bool = False):
    """(n,d),(k,d) -> labels (n,) i32, min squared distances (n,) f32."""
    n, d = points.shape
    k = centroids.shape[0]

    bn = min(block_n, max(8, n))
    bk = min(block_k, max(8, k))
    n_pad = -(-n // bn) * bn
    k_pad = -(-k // bk) * bk
    d_pad = max(-(-d // 128) * 128, 128)

    x = jnp.zeros((n_pad, d_pad), points.dtype).at[:n, :d].set(points)
    c = jnp.zeros((k_pad, d_pad), centroids.dtype).at[:k, :d].set(centroids)
    cn = jnp.sum(c.astype(jnp.float32) ** 2, axis=-1)[None, :]       # (1, k_pad)

    grid = (n_pad // bn, k_pad // bk)
    best, idx = pl.pallas_call(
        functools.partial(_assign_kernel, block_k=bk, k_actual=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(x, c, cn)

    x2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=-1)
    mind = jnp.maximum(best[:n] + x2, 0.0)
    return idx[:n], mind
