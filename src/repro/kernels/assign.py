"""Pallas TPU kernel: tiled nearest-centroid assignment.

The assignment step is the FLOP hot-spot every IPKMeans reducer executes
(n*k*d MACs per Lloyd iteration).  TPU mapping:

  * the ``-2 x.cT`` term is a (bn x d) @ (d x bk) matmul on the MXU
    (``preferred_element_type`` accumulation in the spec's acc dtype);
  * grid = (n_blocks, k_blocks) with k minor: each x-tile stays resident in
    VMEM while centroid tiles stream past it, carrying a running
    (best_score, best_index) pair in the revisited output block — a flash-
    attention-style online reduction, so the (n x k) distance matrix is never
    materialized in HBM;
  * d is zero-padded to the 128-lane boundary (exact for squared-euclidean),
    n and k are padded to block multiples with +inf masking on k.

Block geometry arrives as a :class:`~repro.kernels.specs.KernelSpec`
(``specs.DEFAULT_SPEC`` when unset; autotuned specs via the ``tuned``
engine); the historical loose ``block_n``/``block_k`` ints remain as a
deprecated shim.

``x-norm`` is row-constant so it cannot change the argmin; the kernel reduces
``||c||^2 - 2 x.c`` and the wrapper adds ``||x||^2`` back for the distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import specs
from repro.kernels.specs import KernelSpec


def _assign_kernel(x_ref, c_ref, cn_ref, best_ref, idx_ref, *,
                   block_k: int, k_actual: int, acc):
    j = pl.program_id(1)
    x = x_ref[...].astype(acc)                            # (bn, d)
    c = c_ref[...].astype(acc)                            # (bk, d)
    cn = cn_ref[...].astype(acc)                          # (1, bk)

    # score = ||c||^2 - 2 x.c   (row-constant ||x||^2 omitted)
    s = (cn - 2.0 * jnp.dot(x, c.T, preferred_element_type=acc)
         ).astype(jnp.float32)
    col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < k_actual, s, jnp.inf)             # mask padded centroids

    local_best = jnp.min(s, axis=1)                       # (bn,)
    local_idx = (jnp.argmin(s, axis=1).astype(jnp.int32) + j * block_k)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = local_best
        idx_ref[...] = local_idx

    @pl.when(j > 0)
    def _accumulate():
        prev_best = best_ref[...]
        prev_idx = idx_ref[...]
        take = local_best < prev_best                     # strict: low-index ties win
        best_ref[...] = jnp.where(take, local_best, prev_best)
        idx_ref[...] = jnp.where(take, local_idx, prev_idx)


@functools.partial(jax.jit, static_argnames=("spec",))
def _assign_pallas(points: jnp.ndarray,
                   centroids: jnp.ndarray,
                   *,
                   spec: KernelSpec):
    n, d = points.shape
    k = centroids.shape[0]
    bn, bk, n_pad, k_pad, d_pad = spec.tile_shapes(n, d, k)

    x = jnp.zeros((n_pad, d_pad), points.dtype).at[:n, :d].set(points)
    c = jnp.zeros((k_pad, d_pad), centroids.dtype).at[:k, :d].set(centroids)
    cn = jnp.sum(c.astype(jnp.float32) ** 2, axis=-1)[None, :]       # (1, k_pad)

    grid = (n_pad // bn, k_pad // bk)
    best, idx = pl.pallas_call(
        functools.partial(_assign_kernel, block_k=bk, k_actual=k,
                          acc=jnp.dtype(spec.acc_dtype)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        ],
        interpret=bool(spec.interpret),
    )(x, c, cn)

    x2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=-1)
    mind = jnp.maximum(best[:n] + x2, 0.0)
    return idx[:n], mind


def assign_pallas(points: jnp.ndarray,
                  centroids: jnp.ndarray,
                  *,
                  spec: KernelSpec | None = None,
                  block_n: int | None = None,
                  block_k: int | None = None,
                  interpret: bool | None = None):
    """(n,d),(k,d) -> labels (n,) i32, min squared distances (n,) f32."""
    spec = specs.coerce(spec, block_n=block_n, block_k=block_k,
                        interpret=interpret)
    return _assign_pallas(points, centroids,
                          spec=spec.with_interpret(bool(spec.interpret)))
