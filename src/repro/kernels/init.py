"""Pallas TPU kernel: fused k-means|| initialization round sweep.

Scalable K-Means++ (Bahmani et al., PAPERS.md) replaces k-means++'s k
sequential passes with O(log n) *rounds*: each round scores every point
against the current candidate set and Bernoulli-samples an expected ~ell new
candidates proportionally to their D^2 contribution.  Done naively a round is
three sweeps over the points (score, reduce the potential, sample); this
kernel is the paper's one-job argument applied to seeding — ONE grid sweep
per round does all three:

  * phase 1 (every ``j``): the same flash-attention-style online min
    reduction as ``fused.py`` phase 1 — a ``(bn x d) @ (d x bc)`` MXU matmul
    per candidate tile, running block minimum carried in VMEM scratch.  Only
    the round's NEW candidates are scored: the per-point minimum distance to
    all older candidates arrives as the streamed ``old_mind`` input, so each
    round's work scales with the ~ell fresh candidates, not the whole set.
  * phase 2 (``j == c_blocks-1``): with the candidate minimum complete for
    this x-tile, fold in ``old_mind``, accumulate the new potential
    ``psi = sum(w * mind)`` into a VMEM-resident (1, 1) output, and draw the
    Bernoulli oversample on-chip: point ``x`` is sampled iff

        ``u_x * psi_prev < ell * mind_x``      (i.e. with probability
                                                ``min(1, ell*mind_x/psi_prev)``)

    against a pre-streamed uniform ``u_x`` (host-supplied so the draw is
    reproducible bit-for-bit against the jnp oracle and across backends).

``psi_prev`` is the PREVIOUS round's potential — the one-sweep design choice:
sampling against ``psi_{r-1}`` instead of the in-flight ``psi_r`` is what
lets the potential reduction and the draw share one pass.  Since the
potential is non-increasing in the candidate set, probabilities are only ever
(slightly) conservative, preserving the oversampling guarantees; the driver
(``core/init.py``) seeds ``psi_prev`` with a sampling-free round-0 sweep.
A round whose candidate tile is entirely invalid (``cand_norms`` +inf) leaves
``mind`` unchanged and still draws — exactly Bahmani's round 1, where the
candidate set is just the uniformly-chosen first point.

Padding follows the other kernels: d zero-padded to the 128-lane boundary
(exact for squared euclidean), n/c padded to block multiples.  Invalid
candidate columns carry +inf ``cand_norms`` so they never win the min;
padded/masked points carry weight 0 so they contribute nothing to ``psi``
and are never sampled.  Block geometry arrives as a
:class:`~repro.kernels.specs.KernelSpec` (the candidate tile reuses the
``block_k`` axis); ``KernelSpec.init_vmem_bytes`` prices the working set for
the tuner's candidate pruning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import specs
from repro.kernels.specs import KernelSpec


def _init_sweep_kernel(x_ref, c_ref, cn_ref, om_ref, u_ref, w_ref, pp_ref,
                       mind_ref, samp_ref, psi_ref,
                       best_scr,
                       *, last_j: int, ell: float, acc):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...].astype(acc)                            # (bn, d)
    c = c_ref[...].astype(acc)                            # (bc, d)
    cn = cn_ref[...].astype(jnp.float32)                  # (1, bc), +inf pads

    # --- phase 1: online min over candidate tiles (fused.py phase 1, no
    # argmin — the round only needs the distance, not the label) ---
    # score = ||c||^2 - 2 x.c   (row-constant ||x||^2 added back at flush);
    # invalid candidates arrive with cn == +inf and can never win the min.
    s = (cn.astype(acc)
         - 2.0 * jnp.dot(x, c.T, preferred_element_type=acc)
         ).astype(jnp.float32)
    local_best = jnp.min(s, axis=1)                       # (bn,)

    @pl.when(j == 0)
    def _init_scratch():
        best_scr[...] = local_best

    @pl.when(j > 0)
    def _accumulate_scratch():
        best_scr[...] = jnp.minimum(best_scr[...], local_best)

    # --- phase 2: candidate min is final — fold old_mind, accumulate the
    # potential, and draw the Bernoulli oversample, all without the (n,)
    # distances ever leaving VMEM mid-pass ---
    @pl.when(j == last_j)
    def _flush():
        xf = x.astype(jnp.float32)
        x2 = jnp.sum(xf * xf, axis=1)
        cand_min = jnp.maximum(best_scr[...] + x2, 0.0)   # true sq distance
        mind = jnp.minimum(om_ref[...], cand_min)
        w = w_ref[...]
        u = u_ref[...]
        psi_prev = pp_ref[0, 0]
        # sample iff u * psi_prev < ell * mind  (prob min(1, ell*mind/psi));
        # weight-0 rows and a zero previous potential never sample
        take = jnp.logical_and(u * psi_prev < ell * mind,
                               jnp.logical_and(w > 0.0, psi_prev > 0.0))
        mind_ref[...] = mind
        samp_ref[...] = take.astype(jnp.int32)
        local_psi = jnp.sum(w * mind)[None, None]         # (1, 1)

        @pl.when(i == 0)
        def _init_out():
            psi_ref[...] = local_psi

        @pl.when(i > 0)
        def _accumulate_out():
            psi_ref[...] += local_psi


@functools.partial(jax.jit, static_argnames=("ell", "spec"))
def _init_sweep(points: jnp.ndarray,
                cands: jnp.ndarray,
                cand_norms: jnp.ndarray,
                old_mind: jnp.ndarray,
                uniforms: jnp.ndarray,
                weights: jnp.ndarray,
                psi_prev: jnp.ndarray,
                *,
                ell: float,
                spec: KernelSpec):
    n, d = points.shape
    c = cands.shape[0]
    bn, bc, n_pad, c_pad, d_pad = spec.tile_shapes(n, d, c)

    x = jnp.zeros((n_pad, d_pad), points.dtype).at[:n, :d].set(points)
    cd = jnp.zeros((c_pad, d_pad), cands.dtype).at[:c, :d].set(cands)
    # padded candidate columns must never win the min: +inf norms
    cn = jnp.full((1, c_pad), jnp.inf, jnp.float32).at[0, :c].set(
        cand_norms.astype(jnp.float32))
    om = jnp.zeros((n_pad,), jnp.float32).at[:n].set(
        old_mind.astype(jnp.float32))
    u = jnp.ones((n_pad,), jnp.float32).at[:n].set(
        uniforms.astype(jnp.float32))
    w = jnp.zeros((n_pad,), jnp.float32).at[:n].set(
        weights.astype(jnp.float32))
    pp = jnp.reshape(psi_prev.astype(jnp.float32), (1, 1))

    grid = (n_pad // bn, c_pad // bc)
    mind, samp, psi = pl.pallas_call(
        functools.partial(_init_sweep_kernel, last_j=grid[1] - 1,
                          ell=float(ell), acc=jnp.dtype(spec.acc_dtype)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),               # running block min
        ],
        interpret=bool(spec.interpret),
    )(x, cd, cn, om, u, w, pp)
    return mind[:n], samp[:n] > 0, psi[0, 0]


def init_sweep(points: jnp.ndarray,
               cands: jnp.ndarray,
               old_mind: jnp.ndarray,
               uniforms: jnp.ndarray,
               psi_prev,
               *,
               ell: float,
               cand_valid: jnp.ndarray | None = None,
               weights: jnp.ndarray | None = None,
               spec: KernelSpec | None = None,
               interpret: bool | None = None):
    """One fused k-means|| round: (n,d),(c,d),(n,),(n,),() ->
    (new_mind (n,) f32, sampled (n,) bool, psi () f32).

    ``cands`` are the round's NEW candidates only (the running minimum
    against all older candidates is ``old_mind``; pass ``+inf`` for the very
    first sweep).  ``cand_valid`` masks padded candidate rows (None: all
    valid); ``weights`` masks padded points and weights the potential (None:
    all-ones).  ``uniforms`` are the round's pre-drawn U[0,1) variates — one
    per point, host-supplied so kernel and oracle draw identically.
    ``psi_prev`` is the previous round's potential; 0 disables sampling
    (the driver's round-0 scoring sweep).  ``ell`` is the oversampling
    factor (static).
    """
    spec = specs.coerce(spec, interpret=interpret)
    if spec.interpret is None:
        spec = spec.with_interpret(jax.default_backend() != "tpu")
    n = points.shape[0]
    c = cands.shape[0]
    norms = jnp.sum(cands.astype(jnp.float32) ** 2, axis=-1)
    if cand_valid is not None:
        norms = jnp.where(cand_valid, norms, jnp.inf)
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    return _init_sweep(points, cands, norms, old_mind, uniforms, w,
                       jnp.asarray(psi_prev, jnp.float32),
                       ell=float(ell), spec=spec)
