"""Public jit'd wrappers around the Pallas kernels.

On the TPU target the kernels compile natively; on this CPU container they
execute via ``interpret=True`` (Pallas's Python interpreter), which is what
the correctness sweeps in tests/test_kernels.py exercise against ref.py.
"""
from __future__ import annotations

import jax

from repro.kernels.assign import assign_pallas
from repro.kernels.centroid_update import centroid_update_pallas
from repro.kernels.fused import lloyd_step_fused as _lloyd_step_fused
from repro.kernels.resident import lloyd_solve_resident as _lloyd_solve_resident
from repro.kernels import ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def assign(points, centroids, *, block_n: int = 256, block_k: int = 128,
           interpret: bool | None = None):
    """Nearest-centroid labels + min squared distances via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    return assign_pallas(points, centroids, block_n=block_n,
                         block_k=block_k, interpret=interpret)


def centroid_update(points, labels, weights, k: int, *, block_n: int = 512,
                    interpret: bool | None = None):
    """Weighted per-cluster (sums, counts) via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    return centroid_update_pallas(points, labels, weights, k,
                                  block_n=block_n, interpret=interpret)


def lloyd_step_fused(points, centroids, weights=None, *, block_n: int = 256,
                     block_k: int = 128, interpret: bool | None = None):
    """One fused Lloyd pass -> (sums (k,d), counts (k,), sse ()) — the
    single-sweep kernel; points are read from HBM once per iteration."""
    if interpret is None:
        interpret = _interpret_default()
    return _lloyd_step_fused(points, centroids, weights,
                             block_n=block_n, block_k=block_k,
                             interpret=interpret)


def lloyd_assign_fused(points, centroids, *, block_n: int = 256,
                       block_k: int = 128, interpret: bool | None = None):
    """Labels + min squared distances from the fused kernel's final-pass
    labels output — one sweep, no second kernel (for cluster dumps and
    solver final statistics)."""
    if interpret is None:
        interpret = _interpret_default()
    _, _, _, labels, mind = _lloyd_step_fused(
        points, centroids, None, block_n=block_n, block_k=block_k,
        interpret=interpret, return_labels=True)
    return labels, mind


def lloyd_solve_resident(points, centroids, weights=None, *,
                         max_iters: int = 300, tol: float = 1e-6,
                         interpret: bool | None = None):
    """Whole Lloyd solve in ONE kernel launch (VMEM-resident loop) ->
    (centroids (k,d), sse (), iters () i32, converged () bool).  Points
    stream from HBM once per solve; see kernels/resident.py for the
    feasibility contract."""
    if interpret is None:
        interpret = _interpret_default()
    return _lloyd_solve_resident(points, centroids, weights,
                                 max_iters=max_iters, tol=tol,
                                 interpret=interpret)


# re-export oracles so callers can switch implementations uniformly
assign_ref = ref.assign_ref
centroid_update_ref = ref.centroid_update_ref
lloyd_step_ref = ref.lloyd_step_ref
lloyd_solve_ref = ref.lloyd_solve_ref
