"""Public jit'd wrappers around the Pallas kernels.

On the TPU target the kernels compile natively; on this CPU container they
execute via ``interpret=True`` (Pallas's Python interpreter), which is what
the correctness sweeps in tests/test_kernels.py exercise against ref.py.

Geometry: every wrapper takes an optional :class:`~repro.kernels.specs
.KernelSpec` (``spec=``).  ``None`` means the module default for that kernel
(``specs.DEFAULT_SPEC`` / ``specs.UPDATE_DEFAULT_SPEC``); the engine layer
passes whatever its ``resolve_spec`` hook returns, which is how autotuned
winners reach the kernels.  The pre-spec loose ``block_n``/``block_k`` ints
are still accepted as a deprecated shim.  A spec whose ``interpret`` is
``None`` picks up this module's policy: compiled on TPU, interpreted
elsewhere.
"""
from __future__ import annotations

import jax

from repro.kernels import ref, specs
from repro.kernels.assign import assign_pallas
from repro.kernels.batch_resident import (
    lloyd_solve_batched as _lloyd_solve_batched_kernel)
from repro.kernels.centroid_update import centroid_update_pallas
from repro.kernels.fused import lloyd_step_fused as _lloyd_step_fused
from repro.kernels.init import init_sweep as _init_sweep
from repro.kernels.resident import lloyd_solve_resident as _lloyd_solve_resident
from repro.kernels.specs import KernelSpec


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(spec, block_n, block_k, interpret, default) -> KernelSpec:
    spec = specs.coerce(spec, block_n=block_n, block_k=block_k,
                        interpret=interpret, default=default)
    if spec.interpret is None:
        spec = spec.with_interpret(_interpret_default())
    return spec


def assign(points, centroids, *, spec: KernelSpec | None = None,
           block_n: int | None = None, block_k: int | None = None,
           interpret: bool | None = None):
    """Nearest-centroid labels + min squared distances via the Pallas kernel."""
    spec = _resolve(spec, block_n, block_k, interpret, specs.DEFAULT_SPEC)
    return assign_pallas(points, centroids, spec=spec)


def centroid_update(points, labels, weights, k: int, *,
                    spec: KernelSpec | None = None,
                    block_n: int | None = None,
                    interpret: bool | None = None):
    """Weighted per-cluster (sums, counts) via the Pallas kernel."""
    spec = _resolve(spec, block_n, None, interpret,
                    specs.UPDATE_DEFAULT_SPEC)
    return centroid_update_pallas(points, labels, weights, k, spec=spec)


def lloyd_step_fused(points, centroids, weights=None, *,
                     spec: KernelSpec | None = None,
                     block_n: int | None = None, block_k: int | None = None,
                     interpret: bool | None = None):
    """One fused Lloyd pass -> (sums (k,d), counts (k,), sse ()) — the
    single-sweep kernel; points are read from HBM once per iteration."""
    spec = _resolve(spec, block_n, block_k, interpret, specs.DEFAULT_SPEC)
    return _lloyd_step_fused(points, centroids, weights, spec=spec)


def lloyd_assign_fused(points, centroids, *,
                       spec: KernelSpec | None = None,
                       block_n: int | None = None, block_k: int | None = None,
                       interpret: bool | None = None):
    """Labels + min squared distances from the fused kernel's assign-only
    mode — one sweep, no second kernel, and (since the serving tier made
    this the query hot path) none of the phase-2 accumulator work either:
    the sums/counts/SSE blocks are never allocated or written, so the sweep
    pays only the phase-1 reads plus two ``(bn,)`` stores per x-tile.
    Labels and distances are bit-for-bit the full sweep's (same phase-1
    argmin) — cluster dumps, solver final statistics, and the serving
    endpoint all share this path."""
    spec = _resolve(spec, block_n, block_k, interpret, specs.DEFAULT_SPEC)
    return _lloyd_step_fused(points, centroids, None, spec=spec,
                             return_labels=True, assign_only=True)


def init_sweep(points, cands, old_mind, uniforms, psi_prev, *, ell: float,
               cand_valid=None, weights=None,
               spec: KernelSpec | None = None,
               interpret: bool | None = None):
    """One fused k-means|| init round (``kernels/init.py``): fold the round's
    new candidates into the running per-point min squared distance, reduce
    the new potential, and Bernoulli-oversample the next candidates — all in
    ONE sweep over the points -> (new_mind (n,) f32, sampled (n,) bool,
    psi () f32).  ``uniforms`` are host-drawn U[0,1) variates (one per
    point), so results are bit-for-bit vs ``ref.init_sweep_ref``."""
    spec = _resolve(spec, None, None, interpret, specs.DEFAULT_SPEC)
    return _init_sweep(points, cands, old_mind, uniforms, psi_prev, ell=ell,
                       cand_valid=cand_valid, weights=weights, spec=spec)


def lloyd_solve_resident(points, centroids, weights=None, *,
                         max_iters: int = 300, tol: float = 1e-6,
                         spec: KernelSpec | None = None,
                         interpret: bool | None = None,
                         reseed_empty: bool = False,
                         prune: str = "none",
                         bound_block: int | None = None,
                         return_skips: bool = False):
    """Whole Lloyd solve in ONE kernel launch (VMEM-resident loop) ->
    (centroids (k,d), sse (), iters () i32, converged () bool).  Points
    stream from HBM once per solve; ``reseed_empty`` folds the farthest-
    point empty-cluster reseed into the on-chip loop (still one launch);
    ``prune="bounds"`` adds Hamerly-style bound-gated block skipping to the
    on-chip loop (bit-for-bit-identical result; ``return_skips=True``
    appends the (max_iters, 2) [skipped, total] block counters); see
    kernels/resident.py for the feasibility contract (budget from the
    chip's DeviceProfile)."""
    if interpret is None:
        interpret = (spec.interpret if spec is not None else None)
    if interpret is None:
        interpret = _interpret_default()
    return _lloyd_solve_resident(points, centroids, weights,
                                 max_iters=max_iters, tol=tol,
                                 interpret=interpret,
                                 reseed_empty=reseed_empty,
                                 prune=prune, bound_block=bound_block,
                                 return_skips=return_skips)


def lloyd_solve_batched(subsets, centroids, weights=None, *,
                        group_t: int | None = None,
                        max_iters: int = 300, tol: float = 1e-6,
                        spec: KernelSpec | None = None,
                        interpret: bool | None = None,
                        reseed_empty: bool = False,
                        prune: str = "none",
                        bound_block: int | None = None,
                        return_skips: bool = False):
    """A whole STACK of Lloyd solves in ONE pipelined kernel launch:
    (M,S,d),(k,d)[,(M,S)] -> (centroids (M,k,d), sse (M,), iters (M,) i32,
    converged (M,) bool).  ``group_t`` is the subsets-per-grid-step batch
    (default: the spec's tuned ``group_t``, else fill the DeviceProfile
    budget); ``reseed_empty`` folds the per-lane farthest-point reseed into
    the group loop (still one launch per stack); ``prune="bounds"`` adds
    bound-gated block skipping at group granularity (bit-for-bit-identical
    results; ``return_skips=True`` appends the (max_iters, 2) stack-summed
    [skipped, live] lane-block counters); see kernels/batch_resident.py for
    the feasibility contract."""
    if interpret is None:
        interpret = (spec.interpret if spec is not None else None)
    if interpret is None:
        interpret = _interpret_default()
    return _lloyd_solve_batched_kernel(subsets, centroids, weights,
                                       group_t=group_t,
                                       max_iters=max_iters, tol=tol,
                                       spec=spec, interpret=interpret,
                                       reseed_empty=reseed_empty,
                                       prune=prune, bound_block=bound_block,
                                       return_skips=return_skips)


# re-export oracles so callers can switch implementations uniformly
assign_ref = ref.assign_ref
centroid_update_ref = ref.centroid_update_ref
lloyd_step_ref = ref.lloyd_step_ref
lloyd_solve_ref = ref.lloyd_solve_ref
lloyd_solve_bounds_ref = ref.lloyd_solve_bounds_ref
init_sweep_ref = ref.init_sweep_ref
