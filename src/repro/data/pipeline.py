"""Deterministic, shardable, resumable token pipeline for LM training.

Production shape: every (step, host) pair maps to a disjoint slice of a
deterministic random stream, so the pipeline is

  * stateless-resumable — restoring from a checkpoint at step S reproduces
    the exact batch sequence without replaying S steps;
  * elastic — the global batch is laid out in logical order and sliced by
    host id, so changing host count re-shards cleanly;
  * straggler-tolerant — batch(step) is pure, any host can recompute any
    other host's shard if the coordinator reassigns work.

Synthetic corpus (hash-mixed token ids) stands in for a tokenized dataset;
the interface (``batch(step)`` -> {tokens, labels, mask}) is what a real
loader would expose.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    @property
    def host_batch(self) -> int:
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide by num_hosts")
        return self.global_batch // self.num_hosts


class TokenPipeline:
    """Pure-function batch source: batch(step) is reproducible forever."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        # fold (seed, step, host) into one stream; threefry is cheap on CPU
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed), step), cfg.host_id)
        tokens = jax.random.randint(
            key, (cfg.host_batch, cfg.seq_len + 1), 0, cfg.vocab_size,
            dtype=jnp.int32)
        tokens = np.asarray(tokens)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "mask": np.ones((cfg.host_batch, cfg.seq_len), np.float32),
        }

    def reshard(self, num_hosts: int, host_id: int) -> "TokenPipeline":
        """Elastic re-sharding: same stream, new host layout."""
        return TokenPipeline(dataclasses.replace(
            self.cfg, num_hosts=num_hosts, host_id=host_id))
