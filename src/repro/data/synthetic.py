"""Synthetic datasets matching the paper's experimental setup (Section 3).

Dataset 1: 3000 Gaussian 2-D points, 5 clusters (Fig 4) — used by (iii)-(v).
Dataset 2: 15000 points, 4 clusters — used by (vi).
Initial-centroid groups: 5 different groups, fixed per experiment.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("n", "k", "d"))
def gaussian_mixture(key: jax.Array, n: int, k: int, d: int = 2,
                     spread: float = 6.0, sigma: float = 1.0):
    """n points from k isotropic Gaussians with centers ~ U[-spread, spread].

    Returns (points (n,d), true_centers (k,d), component (n,) int32)."""
    kc, kx, ka = jax.random.split(key, 3)
    centers = jax.random.uniform(kc, (k, d), minval=-spread, maxval=spread)
    comp = jax.random.randint(ka, (n,), 0, k)
    noise = jax.random.normal(kx, (n, d)) * sigma
    points = centers[comp] + noise
    return points, centers, comp


def paper_dataset_3000(seed: int = 0):
    """Paper dataset 1: 3000 2-D Gaussian points, 5 clusters.

    Cluster overlap matches the paper's Figure 4 (visibly touching blobs) —
    with well-separated blobs Lloyd converges in <15 iterations and, exactly
    as the paper itself observes for its experiments 2-3, PKMeans' few jobs
    can beat IPKMeans' preprocessing.  Overlap puts the iteration counts in
    the regime where the paper's Fig 5/6 claims live."""
    pts, centers, _ = gaussian_mixture(jax.random.key(seed), 3000, 5,
                                       spread=5.0, sigma=2.0)
    return pts, centers


def paper_dataset_15000(seed: int = 1):
    """Paper dataset 2: 15000 2-D Gaussian points, 4 clusters."""
    pts, centers, _ = gaussian_mixture(jax.random.key(seed), 15000, 4,
                                       spread=5.0, sigma=2.0)
    return pts, centers


def initial_centroid_groups(points: jnp.ndarray, k: int, groups: int = 5,
                            seed: int = 100):
    """The paper's '5 different groups of initial centroids': uniform over
    the data bounding box (Figure 4 shows '+' marks spread over the plane,
    not on data points), deterministic per (seed, group)."""
    lo, hi = points.min(axis=0), points.max(axis=0)
    out = []
    for g in range(groups):
        key = jax.random.key(seed + g)
        out.append(jax.random.uniform(key, (k, points.shape[1]),
                                      minval=lo, maxval=hi))
    return out
