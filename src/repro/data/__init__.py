from repro.data.synthetic import (gaussian_mixture, paper_dataset_3000,
                                  paper_dataset_15000, initial_centroid_groups)
from repro.data.pipeline import TokenPipeline, PipelineConfig

__all__ = [
    "gaussian_mixture", "paper_dataset_3000", "paper_dataset_15000",
    "initial_centroid_groups", "TokenPipeline", "PipelineConfig",
]
