"""repro — IPKMeans (Jin/Cui/Yu 2016) on TPU: JAX/Pallas production framework."""

__version__ = "1.0.0"
