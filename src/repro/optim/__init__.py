from repro.optim import schedules
from repro.optim.adamw import AdamWConfig, AdamWState, init, update

__all__ = ["AdamWConfig", "AdamWState", "init", "update", "schedules"]
