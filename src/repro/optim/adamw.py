"""AdamW with ZeRO-friendly state and optional bf16 moment compression.

State mirrors the (boxed) param tree, so whatever sharding the params carry,
the optimizer state inherits it (ZeRO-1 falls out of FSDP param sharding).
``state_dtype='bfloat16'`` halves optimizer bytes — the knob that lets the
671B config fit 16 GB/chip (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"        # 'float32' | 'bfloat16'
    clip_norm: float | None = 1.0


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(count=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def update(grads, state: AdamWState, params, lr,
           cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = _global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    count = state.count + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(count, new_m, new_v), gnorm
