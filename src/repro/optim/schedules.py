"""LR schedules: cosine, linear, and MiniCPM's WSD (warmup-stable-decay)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr, warmup, total, final_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac * peak_lr + (1 - final_frac) * peak_lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr, warmup, stable, decay, final_frac=0.01):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): linear warmup, long
    flat stage, then a short exponential-ish (here linear-log) decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
    dec = peak_lr * jnp.exp(jnp.log(final_frac) * t)
    return jnp.where(step < warmup, warm,
                     jnp.where(step < warmup + stable, peak_lr, dec))


def linear(step, *, peak_lr, warmup, total, final_frac=0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    return jnp.where(step < warmup, warm,
                     peak_lr * (1 - (1 - final_frac) * t))
