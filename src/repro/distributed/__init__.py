from repro.distributed import compress, runtime, sharding

__all__ = ["compress", "runtime", "sharding"]
