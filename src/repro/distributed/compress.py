"""int8 error-feedback compression for cross-pod (DCN) reduction.

int8 error-feedback quantization: each worker quantizes its local
contribution to int8 with a symmetric scale, keeps the quantization residual
locally, and adds it back next step — unbiased over time (Seide et al. /
1-bit Adam lineage).  Two tree families ride the same machinery:

  * gradient trees (the original use): per-tensor scales, one quantize per
    optimizer step (``compress_grads``/``decompress_grads``);
  * k-means reduction stats — ``{"sums": (M, k, d), "counts": (M, k)}``
    trees — where the residual is carried ACROSS Lloyd iterations inside the
    solver loop and the reduction itself happens here (``ef_allreduce``):
    quantize + all_gather the int8 payload over the pod axis + dequantize-sum
    locally, so only int8 values (plus tiny f32 scales) cross the slow link.
    Per-row scales (``axis=-1``) keep empty/near-empty clusters' rows from
    inheriting a big cluster's scale.

For the multi-pod mesh this cuts the pod-axis all-reduce payload ~4x
(f32 -> int8 + scales) at <1% effective noise (test-verified on both a
convergence run and the Lloyd fixed point).  Also provides plain bf16
reduction casting for the cheap 2x.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any        # pytree like the compressed tree, f32


def init_ef(tree_like):
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), tree_like))


def quantize_int8(x, axis=None):
    """x -> (int8 values, f32 scale).  Symmetric scaling.

    ``axis=None`` is one scale per tensor (the gradient path);
    ``axis=<int or tuple>`` computes per-slice scales with ``keepdims`` so
    dequantization broadcasts (the stats path uses ``axis=-1`` for per-row
    scales: one per (subset, cluster) sums row / one per subset counts
    vector).

    Degeneracy guard: an all-zero slice used to produce a (near-)zero scale
    — exactly zero once a half-precision input underflowed the old 1e-12
    clamp — and ``0/0 -> NaN`` on the quantize (and garbage on dequantize).
    Zero-amax slices now take scale 1.0, so they round-trip to EXACT zeros.
    Empty clusters hit this path every iteration (their sums rows are
    all-zero), so it is load-bearing, not just defensive.
    """
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.where(amax > 0.0, amax, 127.0) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _is_payload(x) -> bool:
    return isinstance(x, tuple)


def compress_tree(tree, state: EFState, axes=None):
    """EF-quantize a pytree -> ((int8, scale) payload tree, new EF state).

    The payload is what crosses the slow link; the residual (what int8
    couldn't represent) stays local and is re-injected next call.  ``axes``
    is an optional pytree matching ``tree`` whose leaves are the ``axis``
    argument each leaf's :func:`quantize_int8` uses (``None`` = per-tensor
    everywhere — the gradient default).
    """
    if axes is None:
        payload = jax.tree.map(
            lambda g, r: quantize_int8(g.astype(jnp.float32) + r),
            tree, state.residual)
    else:
        payload = jax.tree.map(
            lambda g, r, a: quantize_int8(g.astype(jnp.float32) + r, axis=a),
            tree, state.residual, axes)
    residual = jax.tree.map(
        lambda g, r, p: (g.astype(jnp.float32) + r) - dequantize_int8(*p),
        tree, state.residual, payload, is_leaf=_is_payload)
    return payload, EFState(residual=residual)


def compress_grads(grads, state: EFState):
    """The original gradient entry point: per-tensor scales."""
    return compress_tree(grads, state)


def decompress_grads(payload, dtype=jnp.float32):
    return jax.tree.map(lambda p: dequantize_int8(*p).astype(dtype), payload,
                        is_leaf=_is_payload)


def ef_allreduce(tree, state: EFState, axis_name: str, axes=None,
                 return_error_bound: bool = False):
    """int8 error-feedback all-reduce of a stats pytree over a mesh axis.

    Call inside ``shard_map`` (or ``vmap(..., axis_name=...)``): each program
    quantizes its local ``tree`` (+ its carried residual), the int8 payload
    and its scales are all-gathered over ``axis_name`` — int8 is what crosses
    the wire — and every program dequantize-sums the gathered contributions,
    so all programs along the axis hold the SAME reduced f32 tree (which is
    what lets the Lloyd loop's convergence decisions stay consistent across
    pods).  Returns ``(reduced f32 tree, new EFState)``; thread the state
    through the loop carry so the residual feedback keeps the fixed point
    unbiased across iterations.

    ``return_error_bound=True`` appends a third output: a tree of the
    worst-case elementwise dequantization error this call could have made
    (each pod rounds by at most ``scale / 2``, so the bound is the gathered
    scales summed and halved — same shape as each leaf's scale).  Consumers
    use it as a noise floor: a quantized reduction can never settle closer
    to the exact fixed point than this, so convergence thresholds tighter
    than the bound should be widened to it (the cross-pod Lloyd loop does).
    """
    payload, state = compress_tree(tree, state, axes=axes)

    def reduce_leaf(p):
        q, scale = p
        qg = jax.lax.all_gather(q, axis_name)          # int8 on the wire
        sg = jax.lax.all_gather(scale, axis_name)      # tiny f32 sidecar
        return (jnp.sum(qg.astype(jnp.float32) * sg, axis=0),
                0.5 * jnp.sum(sg, axis=0))

    both = jax.tree.map(reduce_leaf, payload, is_leaf=_is_payload)
    reduced = jax.tree.map(lambda b: b[0], both, is_leaf=_is_payload)
    if not return_error_bound:
        return reduced, state
    err = jax.tree.map(lambda b: b[1], both, is_leaf=_is_payload)
    return reduced, state, err


def payload_bytes(tree) -> int:
    """Bytes a pytree occupies on the wire."""
    tot = 0
    for leaf in jax.tree.leaves(tree):
        tot += leaf.size * leaf.dtype.itemsize
    return tot
