"""Gradient compression for cross-pod (DCN) reduction.

int8 error-feedback quantization: each worker quantizes its gradient shard to
int8 with a per-tensor scale, keeps the quantization residual locally, and
adds it back next step — unbiased over time (Seide et al. / 1-bit Adam
lineage).  For the multi-pod mesh this cuts the pod-axis all-reduce payload
4x (bf16) / 4x (f32 -> int8) at <1% effective noise (test-verified on a
convergence run).

Also provides plain bf16 reduction casting for the cheap 2x.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any        # pytree like grads, f32


def init_ef(grads_like):
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_int8(x):
    """x f32 -> (int8 values, scale).  Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, state: EFState):
    """Returns (quantized payload pytree of (int8, scale), new EF state).

    The payload is what crosses the slow link; the residual (what int8
    couldn't represent) stays local and is re-injected next step.
    """
    payload = jax.tree.map(lambda g, r: quantize_int8(g.astype(jnp.float32) + r),
                           grads, state.residual)
    residual = jax.tree.map(
        lambda g, r, p: (g.astype(jnp.float32) + r) - dequantize_int8(*p),
        grads, state.residual, payload,
        is_leaf=lambda x: isinstance(x, tuple))
    return payload, EFState(residual=residual)


def decompress_grads(payload, dtype=jnp.float32):
    return jax.tree.map(lambda p: dequantize_int8(*p).astype(dtype), payload,
                        is_leaf=lambda x: isinstance(x, tuple))


def payload_bytes(tree) -> int:
    """Bytes a pytree occupies on the wire."""
    tot = 0
    for leaf in jax.tree.leaves(tree):
        tot += leaf.size * leaf.dtype.itemsize
    return tot
