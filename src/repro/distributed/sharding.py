"""Logical-axis -> mesh-axis sharding rules.

Parameters carry *logical* axis names (Box aux-data); these rules translate
them into PartitionSpecs for a concrete mesh.  Per-arch overrides let, e.g.,
DeepSeek-V3 shard its 256 experts over the full (data x model) mesh
(expert-parallel degree 256) while mixtral keeps experts replicated and
shards expert d_ff (tensor-parallel FFN).

Rules degrade gracefully: a logical dim that does not divide by its mesh
axes, or whose mesh axis is already taken by an earlier dim of the same
tensor, is replicated — recorded so the dry-run can report what fell back.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.configs.base import ModelConfig
from repro.models.common import Box

# Default logical -> mesh mapping (single- and multi-pod meshes share it;
# 'pod' joins 'data' for batch / ZeRO axes on the multi-pod mesh).
BASE_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "expert_ff": ("model",),
    "experts": ("model",),
    "rnn": ("model",),
    "embed": (),            # replicated by default; FSDP rule overrides
    "head_dim": (),
    "q_lora": (),
    "kv_lora": (),
    "conv": (),
    "layers": (),           # the scan axis — never sharded
}

# FSDP/ZeRO: shard the 'embed' dim of params (and optimizer state) over the
# data axes — required for the >=30B configs to fit 16 GB/chip with AdamW.
FSDP_RULES = {"embed": ("pod", "data")}

# Row-parallel decode layout: weights sharded on their *contracting* (d)
# dim, matching the layout GSPMD's solver prefers inside the decode layer
# scan.  Decode activations are (B,1,d)-tiny, so the per-matmul partial-sum
# psums cost ~MBs while weight movement drops to zero (§Perf cell B).
ROW_PARALLEL_RULES = {
    "embed": ("model",), "heads": (), "kv_heads": (), "ff": (),
    "expert_ff": (), "rnn": ("model",), "q_lora": (), "kv_lora": (),
}

# per-arch overrides
ARCH_RULES: dict[str, dict[str, tuple[str, ...]]] = {
    # DSv3: 256 experts over the whole mesh => EP=256; expert_ff unsharded
    "deepseek-v3-671b": {"experts": ("data", "model"), "expert_ff": ()},
    # multi-pod variant (the 'pod' axis also shards experts: EP=512)
    "deepseek-v3-671b/multipod": {"experts": ("pod", "data", "model"),
                                  "expert_ff": ()},
}

# params >= this many bytes/device replicated => turn on FSDP rules
FSDP_THRESHOLD_PARAMS = 4e9


def rules_for(cfg: ModelConfig, mesh, fsdp: bool | None = None,
              layout: str = "train") -> dict:
    rules = dict(BASE_RULES)
    if layout == "row_parallel":
        rules.update(ROW_PARALLEL_RULES)
        fsdp = False
    if fsdp is None:
        fsdp = cfg.param_count() >= FSDP_THRESHOLD_PARAMS
    if fsdp:
        rules.update(FSDP_RULES)
    multi = "pod" in mesh.axis_names
    if cfg.name in ARCH_RULES:
        rules.update(ARCH_RULES[cfg.name])
    if multi and f"{cfg.name}/multipod" in ARCH_RULES:
        rules.update(ARCH_RULES[f"{cfg.name}/multipod"])
    return rules


def spec_for(shape, axes, rules, mesh) -> P:
    """PartitionSpec for one tensor given its logical axes."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set[str] = set()
    parts = []
    for dim, logical in zip(shape, axes):
        if logical is None or logical not in rules:
            parts.append(None)
            continue
        cand = tuple(a for a in rules[logical]
                     if a in mesh_shape and a not in used)
        size = 1
        for a in cand:
            size *= mesh_shape[a]
        if not cand or size == 1 or dim % size != 0:
            # try progressively shorter prefixes before giving up
            ok = ()
            for cut in range(len(cand) - 1, 0, -1):
                sub = cand[:cut]
                s = 1
                for a in sub:
                    s *= mesh_shape[a]
                if s > 1 and dim % s == 0:
                    ok = sub
                    break
            cand = ok
        if not cand:
            parts.append(None)
            continue
        used.update(cand)
        parts.append(cand if len(cand) > 1 else cand[0])
    return P(*parts)


def param_shardings(boxed_abstract, cfg: ModelConfig, mesh,
                    fsdp: bool | None = None, layout: str = "train"):
    """NamedSharding tree matching a boxed (abstract) param tree.

    Embedding/LM-head tensors (any tensor with a 'vocab' axis) always get
    the 2-D (vocab x embed) layout even when FSDP is off — the logits
    matmul is the one place a decode step has train-sized compute, so its
    sharding must not degrade with the param-layout choice (§Perf cell B).
    """
    rules = rules_for(cfg, mesh, fsdp, layout)
    vocab_rules = dict(rules)
    vocab_rules.update(FSDP_RULES)
    if layout == "row_parallel":
        vocab_rules["vocab"] = ("model",)

    def one(b: Box):
        r = vocab_rules if "vocab" in b.axes else rules
        return NamedSharding(mesh, spec_for(b.value.shape, b.axes, r, mesh))

    return jax.tree.map(one, boxed_abstract,
                        is_leaf=lambda x: isinstance(x, Box))


def batch_shardings(batch_abstract, mesh):
    """Token batches: shard the leading (batch) dim over (pod, data)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        dims = {a: s for a, s in zip(mesh.axis_names, mesh.devices.shape)}
        size = 1
        for a in axes:
            size *= dims[a]
        if leaf.shape[0] % size == 0 and size > 1:
            return NamedSharding(mesh, P(axes, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_abstract)


def cache_shardings(cache_abstract, cfg: ModelConfig, mesh, batch: int):
    """Decode-cache shardings: batch dim over (pod,data) when divisible,
    head-like dims over model; long-context batch=1 falls back to sharding
    the large interior dim (sequence/width) over the data axes."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in mesh_shape)
    data_size = 1
    for a in data_axes:
        data_size *= mesh_shape[a]
    model = mesh_shape.get("model", 1)

    def one(leaf):
        shape = leaf.shape
        parts = [None] * leaf.ndim
        # locate batch dim: first dim equal to `batch` (possibly after a
        # stacked-layer leading dim)
        bdim = None
        for i, s in enumerate(shape[:2]):
            if s == batch:
                bdim = i
                break
        if bdim is not None and batch % data_size == 0 and data_size > 1:
            parts[bdim] = data_axes if len(data_axes) > 1 else data_axes[0]
            placed_data = True
        else:
            placed_data = False
        # shard the biggest remaining dim over model (then data if unused)
        order = sorted(range(leaf.ndim), key=lambda i: -shape[i])
        model_used = False
        for i in order:
            if parts[i] is not None or i == bdim:
                continue
            if not model_used and model > 1 and shape[i] % model == 0 \
                    and shape[i] >= model:
                parts[i] = "model"
                model_used = True
            elif not placed_data and data_size > 1 \
                    and shape[i] % data_size == 0 and shape[i] >= data_size:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                placed_data = True
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_abstract)


def replicated(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree,
                        is_leaf=lambda x: isinstance(x, Box))


# ------------------------------------------------------------------
# k-means pod topology: the IPKMeans mesh is (pods x devices) — the
# subset ("reducer") axis shards over the fast in-pod axis, while each
# subset's POINTS shard over the pod (DCN) axis.  Cross-host traffic is
# then two kinds of summary, never the data: S1's O(R * 256) radix
# histograms per tree round, and S2's per-iteration (sums, counts)
# reduction that ``distributed/compress.ef_allreduce`` compresses.

KMEANS_POD_AXIS = "pods"      # the slow (DCN) axis of a k-means pod mesh
KMEANS_DATA_AXIS = "data"     # the fast (ICI) axis: shards the subset dim


def kmeans_pod_mesh(pods: int, devices_per_pod: int):
    """A ``(pods, devices_per_pod)`` mesh with axes ``("pods", "data")``.

    ``pods`` models the slow cross-host/DCN dimension; ``data`` the fast
    in-pod ICI dimension.  Needs ``pods * devices_per_pod`` visible devices
    (tests virtualize with ``--xla_force_host_platform_device_count``).
    """
    if pods < 1 or devices_per_pod < 1:
        raise ValueError(f"pods={pods} x devices_per_pod={devices_per_pod} "
                         f"must both be >= 1")
    return make_mesh((pods, devices_per_pod),
                     (KMEANS_POD_AXIS, KMEANS_DATA_AXIS))


def subset_specs(subset_axes: tuple[str, ...], pod_axis: str | None):
    """PartitionSpecs for IPKMeans S2 operands on a pod mesh.

    Returns ``(subsets_spec, masks_spec, out_spec)`` for the ``(M, S, d)``
    packed subsets, their ``(M, S)`` masks, and the per-subset outputs: the
    subset axis shards over ``subset_axes``, the in-subset point axis over
    ``pod_axis`` (replicated when ``None`` — the single-mesh layout), and
    every per-subset OUTPUT is replicated along ``pod_axis`` because the
    cross-pod reduction hands all pods the same reduced stats.
    """
    point_part = pod_axis if pod_axis else None
    return (P(subset_axes, point_part, None),
            P(subset_axes, point_part),
            P(subset_axes))


def s1_point_spec(subset_axes: tuple[str, ...],
                  pod_axis: str | None) -> P:
    """PartitionSpec for the raw ``(n, d)`` points entering S1.

    The sharded histogram build/labeler (and the pod a2a pack) expect points
    sharded over ALL mesh axes — ``(pod_axis,) + subset_axes`` — so no
    single shard ever holds the dataset; the d (coordinate) axis stays
    unsharded.  With ``pod_axis=None`` this is the single-mesh layout
    (points over the in-pod axes only).
    """
    axes = ((pod_axis,) + tuple(subset_axes)) if pod_axis \
        else tuple(subset_axes)
    return P(axes, None)
