"""Fault-tolerance / elasticity runtime for large fleets.

On a real multi-pod deployment every host runs this state machine around the
jitted train step; here the protocol is implemented fully and exercised by a
deterministic in-process simulation (tests/test_fault_tolerance.py), since
the container has one process.  The protocol:

  * HEARTBEAT  — every worker stamps (step, wall_time) after each step.
  * FAILURE    — coordinator marks a worker dead after ``heartbeat_timeout``
    without a stamp (or an explicit crash); the fleet drops to the last
    committed checkpoint, rebuilds the mesh from the survivors (elastic
    rescale: the data axis shrinks, per-host batch re-slices via
    TokenPipeline.reshard — batch(step) is pure so no data is lost or
    duplicated), and resumes from checkpoint step.
  * STRAGGLER  — synchronous-with-deadline: a worker whose step time exceeds
    ``straggler_factor`` x fleet median for ``straggler_patience``
    consecutive steps is treated as failed (proactive eviction beats waiting
    on a 10x-slow host at every collective).
  * SCALE-UP   — joining workers wait at the next checkpoint boundary; the
    mesh is rebuilt to include them (same reshard path).

Checkpoint/restart is the repro.checkpoint commit protocol; recovery =
restore_latest onto the new mesh (elastic resharding is a device_put).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_step: int = -1
    last_beat: float = 0.0
    step_times: list = dataclasses.field(default_factory=list)
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class FTConfig:
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    min_workers: int = 1


class Coordinator:
    """Failure detector + elastic membership. Pure logic — host agnostic."""

    def __init__(self, num_workers: int, cfg: FTConfig = FTConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers = {i: WorkerState(i, last_beat=clock())
                        for i in range(num_workers)}
        self.generation = 0          # bumps on every membership change

    # -- worker-side calls --------------------------------------------
    def heartbeat(self, worker_id: int, step: int, step_time: float):
        w = self.workers[worker_id]
        w.last_step = step
        w.last_beat = self.clock()
        w.step_times.append(step_time)
        if len(w.step_times) > 16:
            w.step_times.pop(0)

    def report_failure(self, worker_id: int):
        if self.workers[worker_id].alive:
            self.workers[worker_id].alive = False
            self.generation += 1

    def join(self, worker_id: int):
        self.workers[worker_id] = WorkerState(worker_id,
                                              last_beat=self.clock())
        self.generation += 1

    # -- coordinator sweep --------------------------------------------
    def alive_workers(self) -> list[int]:
        return sorted(i for i, w in self.workers.items() if w.alive)

    def sweep(self) -> dict:
        """Detect dead + straggling workers; returns the actions taken."""
        now = self.clock()
        evicted, reasons = [], {}
        alive = [w for w in self.workers.values() if w.alive]
        med = statistics.median(
            [statistics.median(w.step_times) for w in alive if w.step_times]
        ) if any(w.step_times for w in alive) else None
        for w in alive:
            if now - w.last_beat > self.cfg.heartbeat_timeout:
                evicted.append(w.worker_id)
                reasons[w.worker_id] = "heartbeat-timeout"
            elif (med is not None and
                  len(w.step_times) >= self.cfg.straggler_patience and
                  all(t > self.cfg.straggler_factor * med
                      for t in w.step_times[-self.cfg.straggler_patience:])):
                evicted.append(w.worker_id)
                reasons[w.worker_id] = "straggler"
        for wid in evicted:
            self.workers[wid].alive = False
        if evicted:
            self.generation += 1
        n_alive = len(self.alive_workers())
        if n_alive < self.cfg.min_workers:
            raise RuntimeError(
                f"fleet below min_workers: {n_alive} < {self.cfg.min_workers}")
        return {"evicted": evicted, "reasons": reasons,
                "generation": self.generation}


@dataclasses.dataclass
class RecoveryPlan:
    """What a membership change means for the training job."""
    generation: int
    workers: list[int]
    restart_step: int
    data_shards: int

    @staticmethod
    def build(coord: Coordinator, ckpt_dir, ckpt_step: Optional[int]):
        workers = coord.alive_workers()
        return RecoveryPlan(generation=coord.generation,
                            workers=workers,
                            restart_step=ckpt_step or 0,
                            data_shards=len(workers))


def run_with_recovery(train_one_step, *, num_workers: int, steps: int,
                      save_every: int, save_fn, restore_fn,
                      fail_at: dict | None = None,
                      cfg: FTConfig = FTConfig()):
    """Deterministic fleet simulation driving the protocol end to end.

    ``train_one_step(step, workers) -> state`` advances global state;
    ``save_fn(step)`` / ``restore_fn() -> step`` persist it.
    ``fail_at``: {step: worker_id} crash injections.
    Returns the event log (for assertions).
    """
    coord = Coordinator(num_workers, cfg)
    log = []
    step = 0
    while step < steps:
        crashed = (fail_at or {}).get(step)
        if crashed is not None and coord.workers[crashed].alive:
            coord.report_failure(crashed)
            ckpt_step = restore_fn()
            plan = RecoveryPlan.build(coord, None, ckpt_step)
            log.append(("recover", step, crashed, plan.restart_step,
                        plan.data_shards))
            step = plan.restart_step
            continue
        workers = coord.alive_workers()
        train_one_step(step, workers)
        for w in workers:
            coord.heartbeat(w, step, 1.0)
        if (step + 1) % save_every == 0:
            save_fn(step + 1)
            log.append(("save", step + 1))
        step += 1
    return log
