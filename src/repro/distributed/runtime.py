"""Fault-tolerance / elasticity runtime for large fleets.

On a real multi-pod deployment every host runs this state machine around the
jitted train step; here the protocol is implemented fully and exercised by a
deterministic in-process simulation (tests/test_fault_tolerance.py), since
the container has one process.  The protocol:

  * HEARTBEAT  — every worker stamps (step, wall_time) after each step.
  * FAILURE    — coordinator marks a worker dead after ``heartbeat_timeout``
    without a stamp (or an explicit crash); the fleet drops to the last
    committed checkpoint, rebuilds the mesh from the survivors (elastic
    rescale: the data axis shrinks, per-host batch re-slices via
    TokenPipeline.reshard — batch(step) is pure so no data is lost or
    duplicated), and resumes from checkpoint step.
  * STRAGGLER  — synchronous-with-deadline: a worker whose step time exceeds
    ``straggler_factor`` x fleet median for ``straggler_patience``
    consecutive steps is treated as failed (proactive eviction beats waiting
    on a 10x-slow host at every collective).
  * SCALE-UP   — joining workers wait at the next checkpoint boundary; the
    mesh is rebuilt to include them (same reshard path).

Checkpoint/restart is the repro.checkpoint commit protocol; recovery =
restore_latest onto the new mesh (elastic resharding is a device_put).

Two drivers exercise the protocol: :func:`run_with_recovery` (training: a
failure drops the whole fleet to the checkpoint step) and
:func:`solve_stacks_with_recovery` (IPKMeans S2: reducer stacks are
independent, so a failure re-solves ONLY the dead worker's stack from its
last centroid snapshot while survivors keep their live state —
``RecoveryPlan.stack_owners`` carries the deterministic reassignment).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_step: int = -1
    last_beat: float = 0.0
    step_times: list = dataclasses.field(default_factory=list)
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class FTConfig:
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    min_workers: int = 1


class Coordinator:
    """Failure detector + elastic membership. Pure logic — host agnostic."""

    def __init__(self, num_workers: int, cfg: FTConfig = FTConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers = {i: WorkerState(i, last_beat=clock())
                        for i in range(num_workers)}
        self.generation = 0          # bumps on every membership change

    # -- worker-side calls --------------------------------------------
    def heartbeat(self, worker_id: int, step: int, step_time: float):
        w = self.workers[worker_id]
        w.last_step = step
        w.last_beat = self.clock()
        w.step_times.append(step_time)
        if len(w.step_times) > 16:
            w.step_times.pop(0)

    def report_failure(self, worker_id: int):
        if self.workers[worker_id].alive:
            self.workers[worker_id].alive = False
            self.generation += 1

    def join(self, worker_id: int):
        self.workers[worker_id] = WorkerState(worker_id,
                                              last_beat=self.clock())
        self.generation += 1

    # -- coordinator sweep --------------------------------------------
    def alive_workers(self) -> list[int]:
        return sorted(i for i, w in self.workers.items() if w.alive)

    def sweep(self) -> dict:
        """Detect dead + straggling workers; returns the actions taken."""
        now = self.clock()
        evicted, reasons = [], {}
        alive = [w for w in self.workers.values() if w.alive]
        med = statistics.median(
            [statistics.median(w.step_times) for w in alive if w.step_times]
        ) if any(w.step_times for w in alive) else None
        for w in alive:
            if now - w.last_beat > self.cfg.heartbeat_timeout:
                evicted.append(w.worker_id)
                reasons[w.worker_id] = "heartbeat-timeout"
            elif (med is not None and
                  len(w.step_times) >= self.cfg.straggler_patience and
                  all(t > self.cfg.straggler_factor * med
                      for t in w.step_times[-self.cfg.straggler_patience:])):
                evicted.append(w.worker_id)
                reasons[w.worker_id] = "straggler"
        for wid in evicted:
            self.workers[wid].alive = False
        if evicted:
            self.generation += 1
        n_alive = len(self.alive_workers())
        if n_alive < self.cfg.min_workers:
            raise RuntimeError(
                f"fleet below min_workers: {n_alive} < {self.cfg.min_workers}")
        return {"evicted": evicted, "reasons": reasons,
                "generation": self.generation}


@dataclasses.dataclass
class RecoveryPlan:
    """What a membership change means for the job.

    For training jobs the plan is global (everyone drops to the checkpoint
    step).  For IPKMeans S2 the reducer stacks are INDEPENDENT, so the plan
    additionally carries ``stack_owners``: the survivors keep their live
    state untouched and only the dead workers' stacks — reassigned
    round-robin over the survivors — restart from their last snapshot.
    """
    generation: int
    workers: list[int]
    restart_step: int
    data_shards: int
    stack_owners: Optional[dict] = None     # worker -> list of stack ids

    @staticmethod
    def build(coord: Coordinator, ckpt_dir, ckpt_step: Optional[int],
              stacks: Optional[dict] = None, rebalance: bool = False):
        """``stacks``: the pre-failure worker -> stack-ids map; orphaned
        stacks (owners no longer alive) are reassigned round-robin over the
        survivors, deterministically (sorted), so every worker computes the
        same plan without communication.  ``rebalance=True`` instead deals
        ALL stacks round-robin over the alive workers — the scale-UP plan:
        a joiner would otherwise never receive work, since live owners keep
        their stacks under the orphan-only policy."""
        workers = coord.alive_workers()
        owners = None
        if stacks is not None:
            if rebalance:
                owners = {w: [] for w in workers}
                for i, s in enumerate(
                        sorted(s for ss in stacks.values() for s in ss)):
                    owners[workers[i % len(workers)]].append(s)
            else:
                owners = {w: list(stacks.get(w, ())) for w in workers}
                orphans = sorted(s for w, ss in stacks.items()
                                 if w not in workers for s in ss)
                for i, s in enumerate(orphans):
                    owners[workers[i % len(workers)]].append(s)
        return RecoveryPlan(generation=coord.generation,
                            workers=workers,
                            restart_step=ckpt_step or 0,
                            data_shards=len(workers),
                            stack_owners=owners)


def run_with_recovery(train_one_step, *, num_workers: int, steps: int,
                      save_every: int, save_fn, restore_fn,
                      fail_at: dict | None = None,
                      cfg: FTConfig = FTConfig()):
    """Deterministic fleet simulation driving the protocol end to end.

    ``train_one_step(step, workers) -> state`` advances global state;
    ``save_fn(step)`` / ``restore_fn() -> step`` persist it.
    ``fail_at``: {step: worker_id} crash injections.
    Returns the event log (for assertions).
    """
    coord = Coordinator(num_workers, cfg)
    log = []
    step = 0
    while step < steps:
        crashed = (fail_at or {}).get(step)
        if crashed is not None and coord.workers[crashed].alive:
            coord.report_failure(crashed)
            ckpt_step = restore_fn()
            plan = RecoveryPlan.build(coord, None, ckpt_step)
            log.append(("recover", step, crashed, plan.restart_step,
                        plan.data_shards))
            step = plan.restart_step
            continue
        workers = coord.alive_workers()
        train_one_step(step, workers)
        for w in workers:
            coord.heartbeat(w, step, 1.0)
        if (step + 1) % save_every == 0:
            save_fn(step + 1)
            log.append(("save", step + 1))
        step += 1
    return log


def solve_stacks_with_recovery(advance, init_states, *, num_workers: int,
                               max_rounds: int, snapshot_every: int,
                               fail_at: dict | None = None,
                               rejoin_at: dict | None = None,
                               cfg: FTConfig = FTConfig(),
                               round_time: float = 1.0):
    """IPKMeans S2 under the heartbeat protocol — per-STACK recovery.

    The k-means specialization of :func:`run_with_recovery`: the unit of
    work is a reducer stack (a worker's slice of the M independent S2
    solves), so a failure never restarts the job — survivors keep their
    live state and ONLY the dead worker's stack re-solves from its last
    snapshot (``RecoveryPlan.stack_owners`` reassigns it round-robin).

    ``advance(stack_id, state) -> (state, converged)`` advances one stack's
    Lloyd solve by one round's worth of iterations (Lloyd is Markov in the
    centroids, so chunked advance replays the exact unchunked iteration
    sequence).  ``init_states`` seeds one opaque state per stack; stacks
    start owned round-robin (stack ``s`` -> worker ``s % num_workers``).

    Protocol per round: crash injections from ``fail_at`` ({round: worker})
    silence that worker — it stops heartbeating AND its live (unsnapshotted)
    state is lost, which is what makes the snapshot the recovery point; the
    coordinator's ``sweep()`` evicts it only once ``heartbeat_timeout``
    elapses (rounds advance a deterministic clock by ``round_time``), at
    which point the plan restores the orphaned stacks from their snapshots
    — or from ``init_states`` when no snapshot was ever committed (the
    zero-surviving-checkpoints case).  ``rejoin_at`` ({round: worker}) lets
    an evicted worker re-join; it picks up stacks at the next plan.

    Returns ``(final states, event log, work)`` where ``work`` lists every
    ``(round, worker, stack)`` advance executed — the recomputation
    accounting recovery tests assert on.
    """
    clock = {"t": 0.0}
    coord = Coordinator(num_workers, cfg, clock=lambda: clock["t"])
    owners = {w: [s for s in range(len(init_states))
                  if s % num_workers == w] for w in range(num_workers)}
    live = {s: st for s, st in enumerate(init_states)}
    snapshot = {}                       # stack id -> last committed state
    snapshot_round = {}                 # stack id -> round it was taken
    done = {s: False for s in live}
    crashed: set[int] = set()
    log, work = [], []

    for rnd in range(max_rounds):
        if all(done.values()):
            break
        clock["t"] += round_time
        victim = (fail_at or {}).get(rnd)
        if victim is not None:
            crashed.add(victim)
            log.append(("crash", rnd, victim))
        joiner = (rejoin_at or {}).get(rnd)
        if joiner is not None and joiner not in coord.alive_workers():
            crashed.discard(joiner)
            coord.join(joiner)
            # scale-up plan: deal all stacks over the grown fleet (state
            # transfer is free in-process; on hosts it rides the snapshot)
            plan = RecoveryPlan.build(coord, None, None, stacks=owners,
                                      rebalance=True)
            owners = plan.stack_owners
            log.append(("rejoin", rnd, joiner, plan.generation))
        for w in coord.alive_workers():
            if w in crashed:
                continue                # silent: no work, no heartbeat
            for s in owners.get(w, ()):
                if done[s]:
                    continue
                live[s], done[s] = advance(s, live[s])
                work.append((rnd, w, s))
            coord.heartbeat(w, rnd, round_time)
        if (rnd + 1) % snapshot_every == 0:
            for w in coord.alive_workers():
                if w in crashed:
                    continue            # a dead worker commits nothing
                for s in owners.get(w, ()):
                    snapshot[s] = (live[s], done[s])
                    snapshot_round[s] = rnd
            log.append(("snapshot", rnd))
        swept = coord.sweep()
        if swept["evicted"]:
            orphans = [s for w in swept["evicted"] for s in owners.get(w, ())]
            plan = RecoveryPlan.build(coord, None, None, stacks=owners)
            for s in orphans:
                # the dead worker's live progress is gone with it: the
                # stack restarts from its last snapshot (or from init when
                # it never reached a snapshot boundary — the
                # zero-surviving-checkpoints case)
                live[s], done[s] = snapshot.get(s, (init_states[s], False))
            owners = plan.stack_owners
            log.append(("recover", rnd, tuple(swept["evicted"]),
                        {s: snapshot_round.get(s, -1) for s in orphans},
                        plan.generation))
    return [live[s] for s in sorted(live)], log, work
