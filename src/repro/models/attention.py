"""Attention: GQA with dense / chunked(flash-style) / windowed / decode paths.

``chunked_attention`` is the production path for long sequences: an online-
softmax two-level scan (q-chunks outer, kv-chunks inner) that never
materializes the (S x T) score matrix — O(S * kv_chunk) live memory, which is
what makes the 32k-prefill dry-run cells memory-sane.  The sliding-window
path only visits the ceil(window/kv_chunk)+1 kv chunks a q-chunk can see, so
SWA prefill does O(S * window) work, not O(S^2).

``dense_attention`` is the oracle the chunked path is tested against.

Supports Dq != Dv (needed by MLA whose keys are 192-wide but values 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_heads(q, num_kv_heads):
    """(B,S,H,D) -> (B,S,Hk,G,D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv_heads, h // num_kv_heads, d)


def dense_attention(q, k, v, *, causal=True, window=None, scale=None,
                    q_offset=0):
    """Reference attention.  q (B,S,H,Dq), k (B,T,Hk,Dq), v (B,T,Hk,Dv).

    ``q_offset``: global position of q[0] (for decode-style suffix queries).
    """
    b, s, h, dq = q.shape
    t, hk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else dq ** -0.5
    qh = _split_heads(q, hk).astype(jnp.float32)
    s_mat = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k.astype(jnp.float32)) * scale
    rows = q_offset + jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s_mat = jnp.where(mask[None, None, None], s_mat, NEG_INF)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def _online_update(carry, s_blk, v_blk):
    """One online-softmax accumulation step.
    carry: (acc (..,q,Dv), row_max (..,q), row_sum (..,q));
    s_blk: (.., q, kblk) scores (already masked), v_blk (B,kblk,Hk,Dv)."""
    acc, row_max, row_sum = carry
    blk_max = jnp.max(s_blk, axis=-1)
    new_max = jnp.maximum(row_max, blk_max)
    corr = jnp.exp(row_max - new_max)
    p = jnp.exp(s_blk - new_max[..., None])                  # (b,hk,g,q,kblk)
    row_sum = row_sum * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
    acc = acc * corr[..., None] + pv
    return acc, new_max, row_sum


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_chunk", "kv_chunk", "scale"))
def chunked_attention(q, k, v, *, causal=True, window=None,
                      q_chunk=512, kv_chunk=512, scale=None):
    """Flash-style attention.  Same contract as dense_attention (q_offset=0,
    S == T self-attention)."""
    b, s, h, dq = q.shape
    t, hk, dv = k.shape[1], k.shape[2], v.shape[-1]
    assert s == t, "chunked_attention is for self-attention (S == T)"
    scale = scale if scale is not None else dq ** -0.5
    g = h // hk

    cq = min(q_chunk, s)
    ck = min(kv_chunk, t)
    s_pad = -(-s // cq) * cq
    t_pad = -(-t // ck) * ck
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))

    nq, nk = s_pad // cq, t_pad // ck
    # (Nq, B, cq, Hk, G, Dq) — scan carries one q-chunk at a time
    q_chunks = qp.reshape(b, nq, cq, hk, g, dq).transpose(1, 0, 2, 3, 4, 5)
    k_chunks = kp.reshape(b, nk, ck, hk, dq).transpose(1, 0, 2, 3, 4)
    v_chunks = vp.reshape(b, nk, ck, hk, dv).transpose(1, 0, 2, 3, 4)

    rows_in_chunk = jnp.arange(cq)
    cols_in_chunk = jnp.arange(ck)

    def elem_mask(qi, kj):
        rows = qi * cq + rows_in_chunk[:, None]            # (cq, 1)
        cols = kj * ck + cols_in_chunk[None, :]            # (1, ck)
        m = cols < t                                       # mask kv padding
        if causal:
            m &= cols <= rows
        if window is not None:
            m &= cols > rows - window
        return m                                           # (cq, ck)

    def scores(qc, kc):
        return jnp.einsum("bqhgd,bkhd->bhgqk",
                          qc.astype(jnp.float32),
                          kc.astype(jnp.float32)) * scale

    if window is None:
        # full/causal: stream every kv chunk past each q chunk
        def q_body(_, qi_qc):
            qi, qc = qi_qc

            def kv_body(carry, kj_kc_vc):
                kj, kc, vc = kj_kc_vc
                s_blk = scores(qc, kc)
                s_blk = jnp.where(elem_mask(qi, kj)[None, None, None],
                                  s_blk, NEG_INF)
                return _online_update(carry, s_blk, vc), None

            acc0 = jnp.zeros((b, hk, g, cq, dv), jnp.float32)
            m0 = jnp.full((b, hk, g, cq), NEG_INF, jnp.float32)
            s0 = jnp.zeros((b, hk, g, cq), jnp.float32)
            (acc, _, rs), _ = jax.lax.scan(
                kv_body, (acc0, m0, s0),
                (jnp.arange(nk), k_chunks, v_chunks))
            out = acc / jnp.maximum(rs[..., None], 1e-30)
            return None, out

        _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), q_chunks))
    else:
        # sliding window: q chunk qi needs cols [qi*cq - window + 1,
        # qi*cq + cq - 1], i.e. at most this many kv chunks (chunk grids of
        # q and kv need not be aligned):
        n_chunks = -(-(window + cq - 1) // ck) + 1

        def q_body(_, qi_qc):
            qi, qc = qi_qc
            kj_hi = (qi * cq + cq - 1) // ck               # diagonal kv chunk
            acc0 = jnp.zeros((b, hk, g, cq, dv), jnp.float32)
            m0 = jnp.full((b, hk, g, cq), NEG_INF, jnp.float32)
            s0 = jnp.zeros((b, hk, g, cq), jnp.float32)

            def off_body(carry, off):
                kj = kj_hi - off
                kj_c = jnp.clip(kj, 0, nk - 1)
                kc = jax.lax.dynamic_index_in_dim(k_chunks, kj_c, 0, False)
                vc = jax.lax.dynamic_index_in_dim(v_chunks, kj_c, 0, False)
                s_blk = scores(qc, kc)
                m = elem_mask(qi, kj_c) & (kj >= 0) & (kj < nk)
                s_blk = jnp.where(m[None, None, None], s_blk, NEG_INF)
                return _online_update(carry, s_blk, vc), None

            (acc, _, rs), _ = jax.lax.scan(
                off_body, (acc0, m0, s0), jnp.arange(n_chunks))
            out = acc / jnp.maximum(rs[..., None], 1e-30)
            return None, out

        _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), q_chunks))

    # (Nq, B, Hk, G, cq, Dv) -> (B, S, H, Dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s_pad, h, dv)
    return out[:, :s].astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, scale=None,
              q_chunk=512, kv_chunk=1024, dense_below=1024):
    """Dispatch: dense for short sequences, chunked beyond."""
    if q.shape[1] <= dense_below:
        return dense_attention(q, k, v, causal=causal, window=window,
                               scale=scale)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_chunk=q_chunk, kv_chunk=kv_chunk, scale=scale)


def decode_attention(q, k_cache, v_cache, kv_positions, pos, *,
                     window=None, scale=None):
    """Single-token decode vs. a (ring-)cache.

    q (B,1,H,Dq); caches (B,T,Hk,D*); kv_positions (B,T) i32 — the global
    position each cache slot holds (-1 = empty; ring caches wrap);
    pos () or (B,) i32 current position.  Returns (B,1,H,Dv).
    """
    b, _, h, dq = q.shape
    hk = k_cache.shape[2]
    scale = scale if scale is not None else dq ** -0.5
    qh = _split_heads(q, hk).astype(jnp.float32)           # (B,1,Hk,G,Dq)
    s_mat = jnp.einsum("bqhgd,bthd->bhgqt", qh,
                       k_cache.astype(jnp.float32)) * scale
    pos = jnp.asarray(pos)
    pos_b = pos if pos.ndim else pos[None].repeat(b, 0)    # (B,)
    valid = (kv_positions >= 0) & (kv_positions <= pos_b[:, None])
    if window is not None:
        valid &= kv_positions > (pos_b[:, None] - window)
    s_mat = jnp.where(valid[:, None, None, None, :], s_mat, NEG_INF)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bhgqt,bthd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)
