"""Griffin/RecurrentGemma temporal-mixing block: causal conv + RG-LRU.

The RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
is linear in h, so prefill/train evaluates it with a log-depth
``associative_scan`` over time (the TPU-native equivalent of the paper's
sequential kernel), and decode is a single fused step.  The per-channel decay
is a_t = exp(-c * softplus(L) * r_t) with gates r, i computed from the block
input — all elementwise, VPU-friendly.

State per sequence is just (h (B,W), conv tail (B,conv_width-1,W)) — O(1) in
sequence length, which is why recurrentgemma runs the long_500k decode cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecurrentConfig
from repro.models.common import param, split_keys

_C = 8.0          # Griffin's fixed decay temperature


def init_recurrent_block(key, d_model: int, rcfg: RecurrentConfig, dtype):
    w = rcfg.lru_width or d_model
    ks = split_keys(key, 8)
    return {
        "w_x": param(ks[0], (d_model, w), ("embed", "rnn"), dtype=dtype),
        "w_gate": param(ks[1], (d_model, w), ("embed", "rnn"), dtype=dtype),
        "conv_w": param(ks[2], (rcfg.conv_width, w), ("conv", "rnn"),
                        dtype=dtype, scale=0.1),
        "lambda_": param(ks[3], (w,), ("rnn",), init="ones"),
        "w_r": param(ks[4], (w, w), ("rnn", "rnn"), dtype=dtype),
        "w_i": param(ks[5], (w, w), ("rnn", "rnn"), dtype=dtype),
        "w_out": param(ks[6], (w, d_model), ("rnn", "embed"), dtype=dtype),
    }


def _causal_conv(x, conv_w, tail=None):
    """Depthwise causal conv.  x (B,S,W), conv_w (K,W); ``tail`` (B,K-1,W)
    prepends state for decode.  Returns (out (B,S,W), new_tail)."""
    k = conv_w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xin = jnp.concatenate([tail, x], axis=1)                   # (B,S+K-1,W)
    out = sum(xin[:, i:i + x.shape[1], :] * conv_w[i][None, None, :]
              for i in range(k))
    return out, xin[:, -(k - 1):, :]


def _gates(p, u):
    """Decay log_a (negative) and gated input, elementwise from u (B,S,W)."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["w_r"].value))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["w_i"].value))
    log_a = (-_C * jax.nn.softplus(p["lambda_"].value)[None, None, :]
             * r.astype(jnp.float32))
    gated = (jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
             * i.astype(jnp.float32) * u.astype(jnp.float32))
    return log_a, gated


def rglru_scan(p, u):
    """Full-sequence RG-LRU via associative scan.  u (B,S,W) -> (B,S,W)."""
    log_a, gated = _gates(p, u)

    def combine(e1, e2):
        la1, b1 = e1
        la2, b2 = e2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    la, h = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    return h.astype(u.dtype)


def rglru_step(p, u, h_prev):
    """One decode step.  u (B,1,W), h_prev (B,W) -> (out (B,1,W), h (B,W))."""
    log_a, gated = _gates(p, u)
    h = jnp.exp(log_a[:, 0]) * h_prev.astype(jnp.float32) + gated[:, 0]
    return h[:, None, :].astype(u.dtype), h


def recurrent_block(p, x, state=None):
    """Griffin recurrent block.  x (B,S,d) -> (B,S,d).

    ``state``: None for train/prefill-from-scratch, or dict with
    {'h': (B,W), 'conv': (B,K-1,W)} for decode; returns (out, new_state).
    """
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].value)
    g = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].value))
    if state is None:
        c, conv_tail = _causal_conv(u, p["conv_w"].value)
        y = rglru_scan(p, c)
        h_last = y[:, -1, :].astype(jnp.float32)
        new_state = {"h": h_last, "conv": conv_tail}
    else:
        c, conv_tail = _causal_conv(u, p["conv_w"].value, tail=state["conv"])
        y, h_last = rglru_step(p, c, state["h"])
        new_state = {"h": h_last, "conv": conv_tail}
    out = jnp.einsum("bsw,wd->bsd", g * y, p["w_out"].value)
    return out, new_state


def init_state(batch: int, d_model: int, rcfg: RecurrentConfig, dtype):
    w = rcfg.lru_width or d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, rcfg.conv_width - 1, w), dtype)}
