"""Mixture-of-Experts layer: router + three dispatch strategies.

  * ``dense``  — every expert runs on every token, masked combine.  O(E/topk)
    overcompute; used only as the correctness oracle and for tiny smokes.
  * ``gather`` — static-capacity sort-based dispatch.  Tokens are ranked
    within their expert via a segment-rank (same trick as the k-d tree
    labeling) and gathered into an (E, C, d) tensor; experts run as a vmapped
    FFN.  Suits few-expert models (mixtral: experts replicated, d_ff sharded).
  * ``einsum`` — GShard-style one-hot (T, E, C) dispatch/combine einsums.
    Suits many-expert models (deepseek-v3: experts sharded over the mesh,
    XLA inserts the all_to_all at the T->E resharding boundary).

All strategies drop tokens over capacity (capacity_factor controls waste) —
the classic throughput/quality trade; tests verify gather/einsum == dense
whenever capacity is not exceeded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.compat import shard_map
from repro.configs.base import MoEConfig
from repro.models import common
from repro.models.common import Box, param, split_keys


def init_moe(key, d_model: int, mcfg: MoEConfig, dtype):
    ks = split_keys(key, 8)
    p = {
        "router": param(ks[0], (d_model, mcfg.num_experts),
                        ("embed", "experts"), dtype=jnp.float32),
        "w_gate": param(ks[1], (mcfg.num_experts, d_model, mcfg.d_ff_expert),
                        ("experts", "embed", "expert_ff"), dtype=dtype),
        "w_up": param(ks[2], (mcfg.num_experts, d_model, mcfg.d_ff_expert),
                      ("experts", "embed", "expert_ff"), dtype=dtype),
        "w_down": param(ks[3], (mcfg.num_experts, mcfg.d_ff_expert, d_model),
                        ("experts", "expert_ff", "embed"), dtype=dtype),
    }
    if mcfg.num_shared_experts:
        f = mcfg.d_ff_shared * mcfg.num_shared_experts
        p["shared_gate"] = param(ks[4], (d_model, f), ("embed", "ff"), dtype=dtype)
        p["shared_up"] = param(ks[5], (d_model, f), ("embed", "ff"), dtype=dtype)
        p["shared_down"] = param(ks[6], (f, d_model), ("ff", "embed"), dtype=dtype)
    return p


def _router(x, w_router, mcfg: MoEConfig):
    """Top-k routing.  x (T, d) -> probs (T, K), idx (T, K) i32, aux loss."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)   # (T, E)
    if mcfg.router_score == "softmax":
        scores = jax.nn.softmax(logits, axis=-1)
    elif mcfg.router_score == "sigmoid_norm":                        # DSv3
        scores = jax.nn.sigmoid(logits)
    else:
        raise ValueError(mcfg.router_score)
    top_vals, top_idx = jax.lax.top_k(scores, mcfg.top_k)
    probs = top_vals / jnp.maximum(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)
    probs = probs * mcfg.routed_scaling
    # Switch-style load-balance aux loss: E * sum(frac_tokens * frac_prob)
    e = mcfg.num_experts
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return probs.astype(x.dtype), top_idx.astype(jnp.int32), aux


def _expert_ffn(xe, w_gate, w_up, w_down):
    """(E, C, d) through per-expert SwiGLU -> (E, C, d)."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    u = jnp.einsum("ecd,edf->ecf", xe, w_up)
    return jnp.einsum("ecf,efd->ecd", g * u, w_down)


def _capacity(t: int, mcfg: MoEConfig) -> int:
    c = int(t * mcfg.top_k / mcfg.num_experts * mcfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _moe_dense(x, p, probs, idx, mcfg):
    t, d = x.shape
    out = jnp.zeros_like(x)
    onehot = jax.nn.one_hot(idx, mcfg.num_experts, dtype=x.dtype)    # (T,K,E)
    gates = jnp.einsum("tk,tke->te", probs.astype(x.dtype), onehot)  # (T,E)
    h = _expert_ffn(jnp.broadcast_to(x, (mcfg.num_experts, t, d)),
                    p["w_gate"].value, p["w_up"].value, p["w_down"].value)
    return jnp.einsum("te,etd->td", gates, h)


def _moe_gather(x, p, probs, idx, mcfg, weights=None):
    """Sort-based dispatch: segment-rank each (token, k) slot within its
    expert, gather to (E, C, d), run experts, scatter-add back.

    ``weights``: optional (w_gate, w_up, w_down) override — used by the
    shard_map-local mode where the boxed params are already unwrapped."""
    wg, wu, wd = weights if weights is not None else (
        p["w_gate"].value, p["w_up"].value, p["w_down"].value)
    t, d = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    cap = _capacity(t, mcfg)
    flat_e = idx.reshape(-1)                                   # (T*K,)
    # rank of each slot within its expert (ties by slot order = token order)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[flat_e[order]].astype(jnp.int32)
    rank = jnp.zeros(t * k, jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    token_of_slot = jnp.arange(t * k) // k
    # gather tokens into expert buckets
    xe = jnp.zeros((e, cap, d), x.dtype)
    xe = xe.at[flat_e, jnp.where(keep, rank, cap)].set(
        x[token_of_slot], mode="drop")
    he = _expert_ffn(xe, wg, wu, wd)
    # combine: weighted scatter-add back to tokens
    gathered = he[flat_e, jnp.clip(rank, 0, cap - 1)]          # (T*K, d)
    w = (probs.reshape(-1)[:, None].astype(x.dtype)
         * keep[:, None].astype(x.dtype))
    out = jnp.zeros_like(x).at[token_of_slot].add(gathered * w)
    return out


def _moe_einsum(x, p, probs, idx, mcfg):
    """GShard capacity dispatch via one-hot einsums (EP-shardable)."""
    t, d = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    cap = _capacity(t, mcfg)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)           # (T, K, E)
    # position of each (t, k) slot in its expert queue: cumsum over slots
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                      # (T*K, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, k)           # (T, K)
    keep = pos < cap
    # dispatch (T, E, C) one-hot over capacity slots
    disp = (jax.nn.one_hot(idx, e, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=x.dtype)[..., None, :])     # (T,K,E,C+1)
    disp = jnp.sum(disp[..., :cap], axis=1)                    # (T, E, C)
    comb = jnp.einsum("tk,tkec->tec", probs.astype(x.dtype),
                      (jax.nn.one_hot(idx, e, dtype=x.dtype)[..., None]
                       * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                                        dtype=x.dtype)[..., None, :cap]))
    xe = jnp.einsum("tec,td->ecd", disp, x)
    xe = common.shard(xe, "model", None, None)                 # EP boundary
    he = _expert_ffn(xe, p["w_gate"].value, p["w_up"].value, p["w_down"].value)
    he = common.shard(he, "model", None, None)
    return jnp.einsum("tec,ecd->td", comb, he)


def _moe_a2a_local(xf, weights, probs, idx, mcfg: MoEConfig, *,
                   ep_axes, num_ranks: int):
    """Per-device body of the expert-parallel all-to-all dispatch.

    Runs INSIDE shard_map: ``xf`` (T_loc, d) are this device's tokens,
    ``weights`` (E_loc, d, f) its expert shard.  Tokens are routed with one
    all_to_all of a (R, C, d) capacity buffer (+ its int sidecar), experts
    compute strictly locally — so expert *gradients* are local too (no
    cross-device grad all-reduce), which is the optimization that moves the
    dsv3 train cell (EXPERIMENTS.md §Perf).
    """
    w_gate, w_up, w_down = weights
    t_loc, d = xf.shape
    e, k = mcfg.num_experts, mcfg.top_k
    r = num_ranks
    e_loc = e // r
    cap = max(8, -(-int(t_loc * k * mcfg.capacity_factor / r) // 8) * 8)

    dest = (idx // e_loc).reshape(-1)                      # (T_loc*K,) rank
    le = (idx % e_loc).reshape(-1)                         # local expert id
    # slot of each assignment within its destination rank
    order = jnp.argsort(dest, stable=True)
    counts = jnp.bincount(dest, length=r)
    starts = jnp.cumsum(counts) - counts
    slot_sorted = jnp.arange(t_loc * k, dtype=jnp.int32) \
        - starts[dest[order]].astype(jnp.int32)
    slot = jnp.zeros(t_loc * k, jnp.int32).at[order].set(slot_sorted)
    keep = slot < cap
    token_of = jnp.arange(t_loc * k) // k

    send_x = jnp.zeros((r, cap, d), xf.dtype).at[
        dest, jnp.where(keep, slot, cap)].set(xf[token_of], mode="drop")
    send_le = jnp.full((r, cap), e_loc, jnp.int32).at[
        dest, jnp.where(keep, slot, cap)].set(le, mode="drop")

    recv_x = jax.lax.all_to_all(send_x, ep_axes, 0, 0, tiled=True)
    recv_le = jax.lax.all_to_all(send_le, ep_axes, 0, 0, tiled=True)

    xin = recv_x.reshape(r * cap, d)
    lein = recv_le.reshape(r * cap)
    if e_loc == 1:
        h = _expert_ffn(xin[None], w_gate, w_up, w_down)[0]
    else:
        # few local experts: masked dense combine over E_loc
        onehot = jax.nn.one_hot(lein, e_loc, dtype=xin.dtype)  # (RC, E_loc)
        hs = _expert_ffn(jnp.broadcast_to(xin, (e_loc,) + xin.shape),
                         w_gate, w_up, w_down)                  # (E_loc,RC,d)
        h = jnp.einsum("ne,end->nd", onehot, hs)
    back = jax.lax.all_to_all(h.reshape(r, cap, d).astype(xf.dtype),
                              ep_axes, 0, 0, tiled=True)

    gathered = back[dest, jnp.clip(slot, 0, cap - 1)]       # (T_loc*K, d)
    w = (probs.reshape(-1)[:, None].astype(xf.dtype)
         * keep[:, None].astype(xf.dtype))
    return jnp.zeros_like(xf).at[token_of].add(gathered * w)


def _moe_a2a(x, p, mcfg: MoEConfig):
    """shard_map wrapper: sequence-parallel tokens, expert-parallel weights.

    Falls back to gather dispatch when no mesh is active or the expert count
    does not divide the expert-parallel rank count.
    """
    mesh = compat.get_abstract_mesh()
    names = dict(zip(mesh.axis_names, mesh.shape.values())) if mesh.shape else {}
    ep_axes = tuple(a for a in ("data", "model") if names.get(a, 1) > 1)
    r = 1
    for a in ep_axes:
        r *= names[a]
    b, s, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if names.get(a, 1) > 1)
    bsz = 1
    for a in batch_axes:
        bsz *= names[a]
    if r <= 1 or mcfg.num_experts % r or b % max(bsz, 1):
        xf = x.reshape(b * s, d)
        probs, idx, aux = _router(xf, p["router"].value, mcfg)
        return _moe_gather(xf, p, probs, idx, mcfg).reshape(b, s, d), aux

    from jax.sharding import PartitionSpec as P
    seq_axis = "model" if (names.get("model", 1) > 1
                           and s % names["model"] == 0) else None
    x_spec = P(batch_axes if batch_axes else None, seq_axis, None)
    ep_spec = P(ep_axes)

    def body(x_loc, router_w, wg, wu, wd):
        bl, sl, _ = x_loc.shape
        xf = x_loc.reshape(bl * sl, d)
        probs, idx, aux = _router(xf, router_w, mcfg)
        out = _moe_a2a_local(xf, (wg, wu, wd), probs, idx, mcfg,
                             ep_axes=ep_axes, num_ranks=r)
        aux = jax.lax.pmean(aux, ep_axes)
        return out.reshape(bl, sl, d), aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), ep_spec, ep_spec, ep_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"].value, p["w_gate"].value, p["w_up"].value,
      p["w_down"].value)
    # named so a remat policy can SAVE the routed output: recomputing it in
    # the backward pass would re-run both all_to_alls (§Perf A4)
    from jax.ad_checkpoint import checkpoint_name
    out = checkpoint_name(out, "moe_out")
    return out, aux


def _moe_local(x, p, mcfg: MoEConfig):
    """shard_map-local gather dispatch for few-expert models (mixtral).

    Every device holds ALL experts with d_ff TP-sharded over 'model', and
    routes only its own (batch-sharded) tokens — the global-view gather
    formulation lets GSPMD lower the combine scatter as a dataset-sized
    all-reduce, while here the only collective is one (T_loc, d) psum per
    layer from the ff-sharded down-projection (§Perf mixtral-prefill cell).
    """
    mesh = compat.get_abstract_mesh()
    names = dict(zip(mesh.axis_names, mesh.shape.values())) if mesh.shape else {}
    batch_axes = tuple(a for a in ("pod", "data") if names.get(a, 1) > 1)
    model = names.get("model", 1)
    b, s, d = x.shape
    bsz = 1
    for a in batch_axes:
        bsz *= names[a]
    if (not batch_axes and model <= 1) or b % max(bsz, 1) \
            or mcfg.d_ff_expert % max(model, 1):
        xf = x.reshape(b * s, d)
        probs, idx, aux = _router(xf, p["router"].value, mcfg)
        return _moe_gather(xf, p, probs, idx, mcfg).reshape(b, s, d), aux

    from jax.sharding import PartitionSpec as P
    x_spec = P(batch_axes if batch_axes else None, None, None)

    def body(x_loc, router_w, wg, wu, wd):
        bl, sl, _ = x_loc.shape
        xf = x_loc.reshape(bl * sl, d)
        probs, idx, aux = _router(xf, router_w, mcfg)
        out = _moe_gather(xf, None, probs, idx, mcfg, weights=(wg, wu, wd))
        if model > 1:
            out = jax.lax.psum(out, "model")       # ff-sharded partials
            aux = jax.lax.pmean(aux, "model")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out.reshape(bl, sl, d), aux

    return shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None, None, "model"),
                  P(None, None, "model"), P(None, "model", None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"].value, p["w_gate"].value, p["w_up"].value,
      p["w_down"].value)


def moe_ffn(x, p, mcfg: MoEConfig):
    """x (B, S, d) -> (B, S, d); returns (out, aux_loss)."""
    b, s, d = x.shape
    if mcfg.dispatch == "local":
        out, aux = _moe_local(x, p, mcfg)
        if mcfg.num_shared_experts:
            out = out + common.swiglu(x, p["shared_gate"].value,
                                      p["shared_up"].value,
                                      p["shared_down"].value)
        from jax.ad_checkpoint import checkpoint_name
        return checkpoint_name(out, "moe_out"), aux
    if mcfg.dispatch == "a2a":
        out, aux = _moe_a2a(x, p, mcfg)
        if mcfg.num_shared_experts:
            out = out + common.swiglu(x, p["shared_gate"].value,
                                      p["shared_up"].value,
                                      p["shared_down"].value)
        return out, aux
    xf = x.reshape(b * s, d)
    probs, idx, aux = _router(xf, p["router"].value, mcfg)
    fn = {"dense": _moe_dense, "gather": _moe_gather,
          "einsum": _moe_einsum}[mcfg.dispatch]
    out = fn(xf, p, probs, idx, mcfg)
    if mcfg.num_shared_experts:
        out = out + common.swiglu(xf, p["shared_gate"].value,
                                  p["shared_up"].value,
                                  p["shared_down"].value)
    return out.reshape(b, s, d), aux
