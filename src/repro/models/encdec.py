"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: bidirectional dense layers over precomputed modality-frontend
embeddings (the audio frontend is a STUB per the assignment — input_specs
provides (B, S_enc, d) frame embeddings).  Decoder: causal self-attention +
cross-attention to the encoder memory + SwiGLU, with KV caching for decode
(cross K/V computed once at prefill and frozen).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.common import (apply_norm, apply_rope, norm_init, param,
                                 split_keys, shard)
from repro.models.transformer import (_attn_sublayer, _dtype, _mlp, init_attn,
                                      init_mlp)


def init_encdec_params(key, cfg: ModelConfig):
    ks = split_keys(key, 8)
    dt = _dtype(cfg)

    def enc_layer(k):
        kk = split_keys(k, 4)
        return {"norm1": norm_init(kk[0], cfg.d_model, cfg.norm),
                "attn": init_attn(kk[1], cfg),
                "norm2": norm_init(kk[2], cfg.d_model, cfg.norm),
                "ffn": init_mlp(kk[3], cfg)}

    def dec_layer(k):
        kk = split_keys(k, 6)
        return {"norm1": norm_init(kk[0], cfg.d_model, cfg.norm),
                "self_attn": init_attn(kk[1], cfg),
                "norm_x": norm_init(kk[2], cfg.d_model, cfg.norm),
                "cross_attn": init_attn(kk[3], cfg),
                "norm2": norm_init(kk[4], cfg.d_model, cfg.norm),
                "ffn": init_mlp(kk[5], cfg)}

    enc_keys = jnp.stack(split_keys(ks[0], cfg.encoder_layers))
    dec_keys = jnp.stack(split_keys(ks[1], cfg.num_layers))
    from repro.models.common import stack_axes
    return {
        "embed": param(ks[2], (cfg.vocab_size, cfg.d_model),
                       ("vocab", "embed"), dtype=dt, init="embed"),
        "enc_layers": stack_axes(jax.vmap(enc_layer)(enc_keys)),
        "enc_norm": norm_init(ks[3], cfg.d_model, cfg.norm),
        "dec_layers": stack_axes(jax.vmap(dec_layer)(dec_keys)),
        "dec_norm": norm_init(ks[4], cfg.d_model, cfg.norm),
        "lm_head": param(ks[5], (cfg.d_model, cfg.vocab_size),
                         ("embed", "vocab"), dtype=dt),
    }


def _cross_attention(p, x, mem_k, mem_v, cfg):
    """Cross-attention with precomputed encoder memory K/V (B,T,Hk,Dh)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].value)
    o = attn_lib.dense_attention(q, mem_k, mem_v, causal=False) \
        if q.shape[1] <= 1024 else \
        _chunked_cross(q, mem_k, mem_v, cfg)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].value)


def _chunked_cross(q, k, v, cfg):
    # non-causal cross attention with S != T: chunk q only
    b, s, h, dh = q.shape
    cq = min(cfg.q_chunk, s)
    s_pad = -(-s // cq) * cq
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    qs = qp.reshape(b, s_pad // cq, cq, h, dh).swapaxes(0, 1)
    outs = jax.lax.map(
        lambda qc: attn_lib.dense_attention(qc, k, v, causal=False), qs)
    return outs.swapaxes(0, 1).reshape(b, s_pad, h, dh)[:, :s]


def encode(params, embeds, cfg: ModelConfig):
    """Frontend embeddings (B,S,d) -> encoder memory (B,S,d)."""
    x = embeds.astype(_dtype(cfg))
    x = shard(x, ("pod", "data"), None, None)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, lp):
        h = apply_norm(x, lp["norm1"].value, cfg.norm)
        q = jnp.einsum("bsd,dhe->bshe", h, lp["attn"]["wq"].value)
        k = jnp.einsum("bsd,dhe->bshe", h, lp["attn"]["wk"].value)
        v = jnp.einsum("bsd,dhe->bshe", h, lp["attn"]["wv"].value)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attn_lib.attention(q, k, v, causal=False,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["attn"]["wo"].value)
        x = x + _mlp(lp["ffn"], apply_norm(x, lp["norm2"].value, cfg.norm))
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) \
        if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return apply_norm(x, params["enc_norm"].value, cfg.norm)


def _memory_kv(params, memory, cfg):
    """Precompute cross-attention K/V per decoder layer (stacked (L,...))."""
    def one(lp):
        k = jnp.einsum("btd,dhe->bthe", memory, lp["cross_attn"]["wk"].value)
        v = jnp.einsum("btd,dhe->bthe", memory, lp["cross_attn"]["wv"].value)
        return k, v
    return jax.vmap(one)(params["dec_layers"])


def decode_train(params, tokens, memory, cfg: ModelConfig):
    """Teacher-forced decoder pass.  Returns logits (B,S,V)."""
    x = (params["embed"].value[tokens] * cfg.embed_scale).astype(_dtype(cfg))
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    mem_k, mem_v = _memory_kv(params, memory, cfg)

    def body(x, xs):
        lp, mk, mv = xs
        h = apply_norm(x, lp["norm1"].value, cfg.norm)
        attn_out, _ = _attn_sublayer(lp["self_attn"], h, positions, cfg,
                                     window=None)
        x = x + attn_out
        hx = apply_norm(x, lp["norm_x"].value, cfg.norm)
        x = x + _cross_attention(lp["cross_attn"], hx, mk, mv, cfg)
        x = x + _mlp(lp["ffn"], apply_norm(x, lp["norm2"].value, cfg.norm))
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) \
        if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, (params["dec_layers"], mem_k, mem_v))
    x = apply_norm(x, params["dec_norm"].value, cfg.norm)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"].value)


def loss_fn(params, batch, cfg: ModelConfig):
    from repro.models.common import cross_entropy_loss
    memory = encode(params, batch["embeds"], cfg)
    logits = decode_train(params, batch["tokens"], memory, cfg)
    ce = cross_entropy_loss(logits, batch["labels"], batch["mask"])
    return ce, {"ce": ce}


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int,
                       enc_len: int):
    dt = _dtype(cfg)
    hk, dh = cfg.num_kv_heads, cfg.head_dim_
    L = cfg.num_layers
    return {
        "self_k": jnp.zeros((L, batch, max_len, hk, dh), dt),
        "self_v": jnp.zeros((L, batch, max_len, hk, dh), dt),
        "kv_pos": jnp.full((batch, max_len), -1, jnp.int32),
        "mem_k": jnp.zeros((L, batch, enc_len, hk, dh), dt),
        "mem_v": jnp.zeros((L, batch, enc_len, hk, dh), dt),
    }


def prefill_memory(params, memory, caches, cfg):
    mem_k, mem_v = _memory_kv(params, memory, cfg)
    return {**caches, "mem_k": mem_k, "mem_v": mem_v}


def decode_step(params, caches, tokens, pos, cfg: ModelConfig):
    """One decoder token against cached self/cross KV."""
    b = tokens.shape[0]
    x = (params["embed"].value[tokens] * cfg.embed_scale).astype(_dtype(cfg))
    positions = jnp.broadcast_to(jnp.asarray(pos)[None, None], (b, 1))
    kv_pos = jax.lax.dynamic_update_slice_in_dim(
        caches["kv_pos"], jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32),
        jnp.asarray(pos), 1)

    def body(x, xs):
        lp, sk, sv, mk, mv = xs
        h = apply_norm(x, lp["norm1"].value, cfg.norm)
        attn_out, new_kv = _attn_sublayer(
            lp["self_attn"], h, positions, cfg, window=None,
            cache={"k": sk, "v": sv, "kv_pos": kv_pos})
        x = x + attn_out
        hx = apply_norm(x, lp["norm_x"].value, cfg.norm)
        x = x + _cross_attention(lp["cross_attn"], hx, mk, mv, cfg)
        x = x + _mlp(lp["ffn"], apply_norm(x, lp["norm2"].value, cfg.norm))
        return x, (new_kv["k"], new_kv["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], caches["self_k"], caches["self_v"],
                  caches["mem_k"], caches["mem_v"]))
    x = apply_norm(x, params["dec_norm"].value, cfg.norm)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].value)
    new_caches = {**caches, "self_k": nk, "self_v": nv, "kv_pos": kv_pos}
    return logits, new_caches
