"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM recurrence (per head, exponential gating, log-space stabilized):
    C_t = f_t C_{t-1} + i_t v_t k_t^T      n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

Linear in (C, n), so we use the *chunkwise-parallel* form: quadratic
attention-style math inside chunks (MXU work) + an O(S/chunk) sequential
carry of the stabilized state across chunks.  ``mlstm_recurrent`` is the
step-by-step oracle; tests assert chunked == recurrent.  The O(1)-size state
is why xlstm runs the long_500k decode cell.

sLSTM keeps per-head scalar memories with recurrent (block-diagonal) gate
mixing — inherently sequential, implemented as a ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.models.common import param, rmsnorm, split_keys


def _logsig(x):
    return jax.nn.log_sigmoid(x.astype(jnp.float32))


# ----------------------------- mLSTM cell -----------------------------

def mlstm_state(batch, heads, dk, dv):
    return {"C": jnp.zeros((batch, heads, dk, dv), jnp.float32),
            "n": jnp.zeros((batch, heads, dk), jnp.float32),
            "m": jnp.full((batch, heads), -1e30, jnp.float32)}


def mlstm_step(q, k, v, i_gate, f_gate, state):
    """One token.  q/k/v (B,H,dk|dv); i_gate/f_gate (B,H) pre-activations."""
    lf = _logsig(f_gate)
    li = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(lf + state["m"], li)
    c_scale = jnp.exp(lf + state["m"] - m_new)[..., None, None]
    i_scale = jnp.exp(li - m_new)[..., None]
    qf = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = c_scale * state["C"] + i_scale[..., None] * (kf[..., :, None] * vf[..., None, :])
    n = c_scale[..., 0] * state["n"] + i_scale * kf
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return h, {"C": C, "n": n, "m": m_new}


def mlstm_recurrent(q, k, v, i_gate, f_gate, state=None):
    """Oracle: scan tokens one by one.  q/k (B,S,H,dk), v (B,S,H,dv),
    gates (B,S,H).  Returns (h (B,S,H,dv), final state)."""
    b, s, h_, dk = q.shape
    dv = v.shape[-1]
    st = state or mlstm_state(b, h_, dk, dv)

    def body(st, xs):
        qt, kt, vt, it, ft = xs
        ht, st = mlstm_step(qt, kt, vt, it, ft, st)
        return st, ht

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_gate, f_gate))
    st, hs = jax.lax.scan(body, st, xs)
    return jnp.moveaxis(hs, 0, 1), st


def mlstm_chunked(q, k, v, i_gate, f_gate, state=None, chunk=64):
    """Chunkwise-parallel mLSTM, matching ``mlstm_recurrent``.

    Within a chunk of length L (positions 1..L, log-forget lf, log-input li):
      b_t   = sum_{s<=t} lf_s                      (inclusive cumsum)
      w_ts  = b_t - b_s + li_s   for s <= t        (intra weights)
      inter weight for query t = b_t + m_prev
    stabilized by m_t = max(max_s w_ts, b_t + m_prev) per position.
    """
    b, s, h_, dk = q.shape
    dv = v.shape[-1]
    st0 = state or mlstm_state(b, h_, dk, dv)
    L = min(chunk, s)
    s_pad = -(-s // L) * L
    pad = lambda t: jnp.pad(t, ((0, 0), (0, s_pad - s)) + ((0, 0),) * (t.ndim - 2))
    qp, kp, vp = pad(q), pad(k), pad(v)
    # pad forget pre-activation with +inf -> lf = 0, li with -inf -> no input
    ip = jnp.pad(i_gate, ((0, 0), (0, s_pad - s), (0, 0)),
                 constant_values=-1e30)
    fp = jnp.pad(f_gate, ((0, 0), (0, s_pad - s), (0, 0)),
                 constant_values=1e30)
    nc = s_pad // L
    resh = lambda t: t.reshape((b, nc, L) + t.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, ic, fc = map(resh, (qp, kp, vp, ip, fp))    # (nc, b, L, ...)

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_body(st, xs):
        qt, kt, vt, it, ft = xs                      # (b, L, H, *)
        lf = _logsig(ft)                             # (b, L, H)
        li = it.astype(jnp.float32)
        bcum = jnp.cumsum(lf, axis=1)                # (b, L, H) inclusive
        btot = bcum[:, -1]                           # (b, H)
        # intra-chunk log-weights w[t, s] = b_t - b_s + li_s (s <= t)
        wts = (bcum[:, :, None, :] - bcum[:, None, :, :]
               + li[:, None, :, :])                  # (b, t, s, H)
        wts = jnp.where(causal[None, :, :, None], wts, -jnp.inf)
        inter = bcum + st["m"][:, None, :]           # (b, t, H)
        m_t = jnp.maximum(jnp.max(wts, axis=2), inter)   # (b, t, H)
        m_t = jnp.maximum(m_t, -1e30)
        dmat = jnp.exp(wts - m_t[:, :, None, :])     # (b, t, s, H)
        qf = qt.astype(jnp.float32) * (dk ** -0.5)
        kf, vf = kt.astype(jnp.float32), vt.astype(jnp.float32)
        scores = jnp.einsum("bthk,bshk->btsh", qf, kf) * dmat
        inter_w = jnp.exp(inter - m_t)               # (b, t, H)
        num = (jnp.einsum("btsh,bshv->bthv", scores, vf)
               + inter_w[..., None]
               * jnp.einsum("bhkv,bthk->bthv", st["C"], qf))
        # scores already contain q.k, so the denominator (n_t . q_t) is the
        # row-sum of scores plus the carried-state term
        den = jnp.sum(scores, axis=2) + inter_w * jnp.einsum(
            "bhk,bthk->bth", st["n"], qf)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- state update to end of chunk ----
        dec = btot[:, None, :] - bcum + li           # (b, s, H) weights
        m_new = jnp.maximum(btot + st["m"], jnp.max(dec, axis=1))
        m_new = jnp.maximum(m_new, -1e30)
        carry_scale = jnp.exp(btot + st["m"] - m_new)            # (b, H)
        in_scale = jnp.exp(dec - m_new[:, None, :])              # (b, s, H)
        C = (carry_scale[..., None, None] * st["C"]
             + jnp.einsum("bsh,bshk,bshv->bhkv", in_scale, kf, vf))
        n = (carry_scale[..., None] * st["n"]
             + jnp.einsum("bsh,bshk->bhk", in_scale, kf))
        return {"C": C, "n": n, "m": m_new}, h

    st, hs = jax.lax.scan(chunk_body, st0, (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 1).reshape(b, s_pad, h_, dv)[:, :s]
    return h.astype(q.dtype), st


# ----------------------------- sLSTM cell -----------------------------

def slstm_state(batch, heads, dh):
    return {"c": jnp.zeros((batch, heads, dh), jnp.float32),
            "n": jnp.zeros((batch, heads, dh), jnp.float32),
            "h": jnp.zeros((batch, heads, dh), jnp.float32),
            "m": jnp.full((batch, heads, dh), -1e30, jnp.float32)}


def slstm_scan(gates_x, r_kernels, state):
    """gates_x: dict i/f/z/o of (B,S,H,dh) input pre-activations;
    r_kernels: dict of (H,dh,dh) recurrent block-diagonal kernels.
    Sequential over S (inherent to sLSTM)."""
    def step(st, xs):
        xi, xf, xz, xo = xs
        rec = {g: jnp.einsum("bhd,hde->bhe", st["h"], r_kernels[g].value)
               for g in ("i", "f", "z", "o")}
        it = (xi + rec["i"]).astype(jnp.float32)
        ft = (xf + rec["f"]).astype(jnp.float32)
        zt = jnp.tanh((xz + rec["z"]).astype(jnp.float32))
        ot = jax.nn.sigmoid((xo + rec["o"]).astype(jnp.float32))
        lf = _logsig(ft)
        m_new = jnp.maximum(lf + st["m"], it)
        c = jnp.exp(lf + st["m"] - m_new) * st["c"] + jnp.exp(it - m_new) * zt
        n = jnp.exp(lf + st["m"] - m_new) * st["n"] + jnp.exp(it - m_new)
        h = ot * c / jnp.maximum(n, 1e-6)
        return {"c": c, "n": n, "h": h, "m": m_new}, h

    xs = tuple(jnp.moveaxis(gates_x[g], 1, 0) for g in ("i", "f", "z", "o"))
    st, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), st
