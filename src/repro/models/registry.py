"""Model registry: config -> init / loss / decode entry points + param math."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.common import unbox


def init_params(key, cfg: ModelConfig):
    if cfg.is_encdec:
        return encdec.init_encdec_params(key, cfg)
    return transformer.init_params(key, cfg)


def abstract_params(cfg: ModelConfig):
    """Boxed abstract param tree (ShapeDtypeStruct leaves) — no allocation."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def loss_fn(cfg: ModelConfig):
    if cfg.is_encdec:
        return lambda p, batch: encdec.loss_fn(p, batch, cfg)
    return lambda p, batch: transformer.loss_fn(p, batch, cfg)


def decode_step_fn(cfg: ModelConfig):
    if cfg.is_encdec:
        return lambda p, caches, tok, pos: encdec.decode_step(p, caches, tok, pos, cfg)
    return lambda p, caches, tok, pos: transformer.decode_step(p, caches, tok, pos, cfg)


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int,
                       enc_len: int = 0):
    if cfg.is_encdec:
        return encdec.init_decode_caches(cfg, batch, max_len,
                                         enc_len or max_len)
    return transformer.init_decode_caches(cfg, batch, max_len)


def count_params_abstract(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from abstract shapes.  ``active_only`` counts
    MoE expert params at top_k/num_experts weight (for 6*N_active*D)."""
    boxed = abstract_params(cfg)
    values, axes = unbox(boxed)
    total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(values)
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and cfg.moe is not None:
            keys = "/".join(str(p) for p in path)
            # expert tensors are (..., E, d, f) — possibly layer-stacked
            if any(w in keys for w in ("w_gate", "w_up", "w_down")) \
                    and "shared" not in keys and leaf.ndim >= 3 \
                    and leaf.shape[-3] == cfg.moe.num_experts:
                n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total
