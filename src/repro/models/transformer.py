"""Config-driven decoder LM assembling every assigned architecture family.

A model is a sequence of homogeneous *layer groups*; each group's parameters
are stacked on a leading axis and executed with ``lax.scan`` (+ optional
remat), keeping HLO size O(#groups) instead of O(#layers) — essential for
compiling 95-layer configs with 512 partitioned devices.

Layer kinds:
  dense     GQA attention (full or sliding) + SwiGLU (or parallel block)
  moe       GQA attention + mixture-of-experts FFN
  mla_dense / mla_moe    DeepSeek-V3 latent attention variants
  griffin   RecurrentGemma residual unit: RG-LRU or local-attn mixer + MLP
  mlstm / slstm          xLSTM blocks (unrolled; 12-layer models)

Decode uses per-group stacked KV/recurrent caches; sliding-window layers use
ring caches of window size so long_500k decode state is O(window), not O(S).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import (apply_norm, apply_rope, cross_entropy_loss,
                                 norm_init, param, split_keys, shard,
                                 stack_axes)

# ---------------------------------------------------------------- groups

def layer_groups(cfg: ModelConfig) -> list[tuple[str, int]]:
    """[(kind, num_layers_in_group), ...] in execution order."""
    if cfg.xlstm is not None:
        return [("slstm" if i in cfg.xlstm.slstm_layers else "mlstm", 1)
                for i in range(cfg.num_layers)]
    if cfg.recurrent is not None:
        pat = cfg.recurrent.pattern
        full, rem = divmod(cfg.num_layers, len(pat))
        groups = [("griffin", full)] if full else []
        for i in range(rem):                       # tail layers, unscanned
            groups.append((f"griffin_tail_{pat[i]}", 1))
        return groups
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        kind = "mla_moe" if cfg.mla is not None else "moe"
        dense_kind = "mla_dense" if cfg.mla is not None else "dense"
        return [(dense_kind, cfg.moe.first_dense_layers),
                (kind, cfg.num_layers - cfg.moe.first_dense_layers)]
    if cfg.moe is not None:
        return [("moe", cfg.num_layers)]
    if cfg.mla is not None:
        return [("mla_dense", cfg.num_layers)]
    return [("dense", cfg.num_layers)]


# ---------------------------------------------------------------- init

def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_attn(key, cfg: ModelConfig):
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = split_keys(key, 6)
    p = {
        "wq": param(ks[0], (d, h, dh), ("embed", "heads", "head_dim"), dtype=_dtype(cfg)),
        "wk": param(ks[1], (d, hk, dh), ("embed", "kv_heads", "head_dim"), dtype=_dtype(cfg)),
        "wv": param(ks[2], (d, hk, dh), ("embed", "kv_heads", "head_dim"), dtype=_dtype(cfg)),
        "wo": param(ks[3], (h, dh, d), ("heads", "head_dim", "embed"), dtype=_dtype(cfg)),
    }
    if cfg.qk_norm:
        p["q_norm"] = param(ks[4], (dh,), ("head_dim",), init="zeros")
        p["k_norm"] = param(ks[5], (dh,), ("head_dim",), init="zeros")
    return p


def init_mlp(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "w_gate": param(ks[0], (d, f), ("embed", "ff"), dtype=_dtype(cfg)),
        "w_up": param(ks[1], (d, f), ("embed", "ff"), dtype=_dtype(cfg)),
        "w_down": param(ks[2], (f, d), ("ff", "embed"), dtype=_dtype(cfg)),
    }


def init_layer(key, cfg: ModelConfig, kind: str):
    ks = split_keys(key, 8)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": norm_init(ks[0], d, cfg.norm)}
    if kind in ("dense", "moe"):
        p["attn"] = init_attn(ks[1], cfg)
        if not cfg.parallel_block:
            p["norm2"] = norm_init(ks[2], d, cfg.norm)
        p["ffn"] = (moe_lib.init_moe(ks[3], d, cfg.moe, _dtype(cfg))
                    if kind == "moe" else init_mlp(ks[3], cfg))
    elif kind in ("mla_dense", "mla_moe"):
        p["attn"] = mla_lib.init_mla(ks[1], d, cfg.num_heads, cfg.mla, _dtype(cfg))
        p["norm2"] = norm_init(ks[2], d, cfg.norm)
        p["ffn"] = (moe_lib.init_moe(ks[3], d, cfg.moe, _dtype(cfg))
                    if kind == "mla_moe" else init_mlp(ks[3], cfg))
    elif kind == "griffin" or kind.startswith("griffin_tail"):
        sub = cfg.recurrent.pattern if kind == "griffin" \
            else (kind.removeprefix("griffin_tail_"),)
        subs = []
        for i, s in enumerate(sub):
            kk = split_keys(ks[4 + (i % 3)], 4)
            sp = {"norm1": norm_init(kk[0], d, cfg.norm),
                  "norm2": norm_init(kk[1], d, cfg.norm),
                  "mlp": init_mlp(kk[2], cfg)}
            if s == "rglru":
                sp["rec"] = rec_lib.init_recurrent_block(kk[3], d, cfg.recurrent, _dtype(cfg))
            else:
                sp["attn"] = init_attn(kk[3], cfg)
            subs.append(sp)
        p["subs"] = subs
    elif kind == "mlstm":
        x = cfg.xlstm
        di = int(x.proj_factor * d)
        hh = x.num_heads
        kk = split_keys(ks[1], 9)
        p.update({
            "w_up": param(kk[0], (d, di), ("embed", "ff"), dtype=_dtype(cfg)),
            "w_z": param(kk[1], (d, di), ("embed", "ff"), dtype=_dtype(cfg)),
            "conv_w": param(kk[2], (4, di), ("conv", "ff"), dtype=_dtype(cfg), scale=0.1),
            "w_q": param(kk[3], (di, di), ("ff", "ff"), dtype=_dtype(cfg)),
            "w_k": param(kk[4], (di, di), ("ff", "ff"), dtype=_dtype(cfg)),
            "w_v": param(kk[5], (di, di), ("ff", "ff"), dtype=_dtype(cfg)),
            "w_i": param(kk[6], (di, hh), ("ff", "heads"), dtype=jnp.float32),
            "w_f": param(kk[7], (di, hh), ("ff", "heads"), dtype=jnp.float32),
            "out_norm": param(kk[8], (di,), ("ff",), init="zeros"),
            "w_down": param(ks[2], (di, d), ("ff", "embed"), dtype=_dtype(cfg)),
        })
    elif kind == "slstm":
        x = cfg.xlstm
        hh = x.num_heads
        dh = d // hh
        f = int(x.slstm_proj_factor * d)
        kk = split_keys(ks[1], 10)
        p.update({
            "conv_w": param(kk[0], (4, d), ("conv", "embed"), dtype=_dtype(cfg), scale=0.1),
            "w_gates": param(kk[1], (d, 4, hh, dh), ("embed", None, "heads", "head_dim"),
                             dtype=_dtype(cfg)),
            "r_i": param(kk[2], (hh, dh, dh), ("heads", "head_dim", None), dtype=_dtype(cfg)),
            "r_f": param(kk[3], (hh, dh, dh), ("heads", "head_dim", None), dtype=_dtype(cfg)),
            "r_z": param(kk[4], (hh, dh, dh), ("heads", "head_dim", None), dtype=_dtype(cfg)),
            "r_o": param(kk[5], (hh, dh, dh), ("heads", "head_dim", None), dtype=_dtype(cfg)),
            "out_norm": param(kk[6], (d,), ("embed",), init="zeros"),
            "norm2": norm_init(kk[7], d, cfg.norm),
            "ffn_up": param(kk[8], (d, 2 * f), ("embed", "ff"), dtype=_dtype(cfg)),
            "ffn_down": param(kk[9], (f, d), ("ff", "embed"), dtype=_dtype(cfg)),
        })
    else:
        raise ValueError(kind)
    return p


def init_params(key, cfg: ModelConfig):
    """Boxed param tree for the full model (decoder-only)."""
    ks = split_keys(key, 4 + len(layer_groups(cfg)))
    params: dict[str, Any] = {
        "embed": param(ks[0], (cfg.vocab_size, cfg.d_model),
                       ("vocab", "embed"), dtype=_dtype(cfg), init="embed"),
        "final_norm": norm_init(ks[1], cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = param(ks[2], (cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"), dtype=_dtype(cfg))
    groups = []
    for gi, (kind, count) in enumerate(layer_groups(cfg)):
        gkey = ks[4 + gi]
        if count == 1:
            groups.append(init_layer(gkey, cfg, kind))
        else:
            lkeys = jnp.stack(split_keys(gkey, count))
            stacked = jax.vmap(lambda k: init_layer(k, cfg, kind))(lkeys)
            groups.append(stack_axes(stacked))
    params["groups"] = groups
    return params


# ---------------------------------------------------------------- forward

def _attn_sublayer(p, x, positions, cfg: ModelConfig, *, window, cache=None):
    """GQA attention.  cache None -> full-sequence; else single-token decode
    against {'k','v','kv_pos'} ring cache (already containing this token)."""
    wq, wk, wv, wo = (p[n].value for n in ("wq", "wk", "wv", "wo"))
    if cache is not None:
        # decode: hard-pin weights at use site — the layer scan otherwise
        # re-shards the whole stacked weight tuple every step (§Perf B)
        wq = shard(wq, None, "model", None)
        wk = shard(wk, None, None, None)
        wv = shard(wv, None, None, None)
        wo = shard(wo, "model", None, None)
    q = jnp.einsum("bsd,dhe->bshe", x, wq)
    k = jnp.einsum("bsd,dhe->bshe", x, wk)
    v = jnp.einsum("bsd,dhe->bshe", x, wv)
    if cfg.qk_norm:
        from repro.models.common import rmsnorm
        q = rmsnorm(q, p["q_norm"].value)
        k = rmsnorm(k, p["k_norm"].value)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, ("pod", "data"), None, "model", None)
    if cache is None:
        o = attn_lib.attention(q, k, v, causal=True, window=window,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        new_kv = (k, v)
    else:
        t = cache["k"].shape[1]
        pos = positions[0, 0]
        slot = pos % t
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
        kv_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["kv_pos"], jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32),
            slot, 1)
        o = attn_lib.decode_attention(q, ck, cv, kv_pos, pos, window=window)
        o = shard(o, ("pod", "data"), None, "model", None)
        new_kv = {"k": ck, "v": cv, "kv_pos": kv_pos}
    out = jnp.einsum("bshe,hed->bsd", o, wo)
    return out, new_kv


def _mlp(p, x, pin: bool = False):
    # explicit ff-axis constraints: keep GSPMD's loop-body layout identical
    # to the stored (ff -> model) weight layout — without them the decode
    # layer scan re-shards the stacked weights every step (§Perf cell B)
    wg, wu, wd = p["w_gate"].value, p["w_up"].value, p["w_down"].value
    if pin:
        wg = shard(wg, None, "model")
        wu = shard(wu, None, "model")
        wd = shard(wd, "model", None)
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, wg))
    u = jnp.einsum("...d,df->...f", x, wu)
    h = shard(g * u, ("pod", "data"), None, "model")
    return jnp.einsum("...f,fd->...d", h, wd)


def _dense_layer(p, x, positions, cfg, kind, cache=None):
    """dense/moe layer.  Returns (x, aux, new_cache)."""
    window = cfg.window if cfg.attention == "sliding" else None
    aux = jnp.zeros((), jnp.float32)
    if cache is not None:
        # pin the residual stream in decode: batch-sharded, d replicated —
        # removes the sharding-solver's freedom to flip the loop body into
        # a weight-resharding fixed point (§Perf cell B iteration log)
        x = shard(x, ("pod", "data"), None, None)
    h = apply_norm(x, p["norm1"].value, cfg.norm)
    attn_out, new_cache = _attn_sublayer(p["attn"], h, positions, cfg,
                                         window=window, cache=cache)
    if cfg.parallel_block:
        ff = _mlp(p["ffn"], h, pin=cache is not None)
        x = x + cfg.residual_scale * (attn_out + ff)
    else:
        x = x + cfg.residual_scale * attn_out
        h2 = apply_norm(x, p["norm2"].value, cfg.norm)
        if kind == "moe":
            ff, aux = moe_lib.moe_ffn(h2, p["ffn"], cfg.moe)
        else:
            ff = _mlp(p["ffn"], h2, pin=cache is not None)
        x = x + cfg.residual_scale * ff
    return x, aux, new_cache


def _mla_layer(p, x, positions, cfg, kind, cache=None):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, p["norm1"].value, cfg.norm)
    if cache is None:
        attn_out = mla_lib.mla_attention(
            p["attn"], h, positions, cfg.mla, cfg.rope_theta,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        new_cache = None
    else:
        t = cache["ckv"].shape[1]
        pos = positions[0, 0]
        ckv_new, kr_new = mla_lib._latents(p["attn"], h, positions,
                                           cfg.mla, cfg.rope_theta)
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, pos, 1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new[:, :, 0, :], pos, 1)
        kv_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["kv_pos"], jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32),
            pos, 1)
        attn_out = mla_lib.mla_decode(p["attn"], h, ckv, kr, kv_pos, pos,
                                      cfg.mla, cfg.rope_theta)
        new_cache = {"ckv": ckv, "kr": kr, "kv_pos": kv_pos}
    x = x + attn_out
    h2 = apply_norm(x, p["norm2"].value, cfg.norm)
    if kind == "mla_moe":
        ff, aux = moe_lib.moe_ffn(h2, p["ffn"], cfg.moe)
    else:
        ff = _mlp(p["ffn"], h2, pin=cache is not None)
    return x + ff, aux, new_cache


def _griffin_layer(p, x, positions, cfg, kind, cache=None):
    """One griffin group element: pattern sub-layers, each mixer + MLP."""
    sub_kinds = cfg.recurrent.pattern if kind == "griffin" \
        else (kind.removeprefix("griffin_tail_"),)
    new_caches = []
    aux = jnp.zeros((), jnp.float32)
    for i, (sk, sp) in enumerate(zip(sub_kinds, p["subs"])):
        h = apply_norm(x, sp["norm1"].value, cfg.norm)
        c_i = None if cache is None else cache[i]
        if sk == "rglru":
            mix, new_c = rec_lib.recurrent_block(sp["rec"], h, state=c_i)
        else:
            mix, new_c = _attn_sublayer(sp["attn"], h, positions, cfg,
                                        window=cfg.recurrent.local_window,
                                        cache=c_i)
        x = x + mix
        x = x + _mlp(sp["mlp"], apply_norm(x, sp["norm2"].value, cfg.norm),
                     pin=cache is not None)
        new_caches.append(new_c)
    return x, aux, new_caches


def _mlstm_layer(p, x, positions, cfg, kind, cache=None):
    xc = cfg.xlstm
    b, s, d = x.shape
    di = p["w_up"].value.shape[1]
    hh = xc.num_heads
    h = apply_norm(x, p["norm1"].value, cfg.norm)
    u = jnp.einsum("bsd,de->bse", h, p["w_up"].value)
    z = jnp.einsum("bsd,de->bse", h, p["w_z"].value)
    conv_tail = None if cache is None else cache["conv"]
    c, new_tail = rec_lib._causal_conv(u, p["conv_w"].value, tail=conv_tail)
    c = jax.nn.silu(c)
    to_heads = lambda t: t.reshape(b, s, hh, di // hh)
    q = to_heads(jnp.einsum("bse,ef->bsf", c, p["w_q"].value))
    k = to_heads(jnp.einsum("bse,ef->bsf", c, p["w_k"].value))
    v = to_heads(jnp.einsum("bse,ef->bsf", u, p["w_v"].value))
    ig = jnp.einsum("bse,eh->bsh", c, p["w_i"].value)
    fg = jnp.einsum("bse,eh->bsh", c, p["w_f"].value)
    st = None if cache is None else cache["cell"]
    if cache is None or s > 1:
        y, new_st = xlstm_lib.mlstm_chunked(q, k, v, ig, fg, state=st,
                                            chunk=xc.chunk_size)
    else:
        y1, new_st = xlstm_lib.mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                          ig[:, 0], fg[:, 0],
                                          st or xlstm_lib.mlstm_state(
                                              b, hh, di // hh, di // hh))
        y = y1[:, None]
    y = y.reshape(b, s, di)
    from repro.models.common import rmsnorm
    y = rmsnorm(y, p["out_norm"].value)
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z), p["w_down"].value)
    new_cache = {"conv": new_tail, "cell": new_st}
    return x + out, jnp.zeros((), jnp.float32), new_cache


def _slstm_layer(p, x, positions, cfg, kind, cache=None):
    xc = cfg.xlstm
    b, s, d = x.shape
    hh = xc.num_heads
    dh = d // hh
    h = apply_norm(x, p["norm1"].value, cfg.norm)
    conv_tail = None if cache is None else cache["conv"]
    c, new_tail = rec_lib._causal_conv(h, p["conv_w"].value, tail=conv_tail)
    c = jax.nn.silu(c)
    w = p["w_gates"].value                                  # (d,4,H,dh)
    gx = {g: jnp.einsum("bsd,dhe->bshe", src, w[:, gi])
          for gi, (g, src) in enumerate(
              (("i", c), ("f", c), ("z", h), ("o", h)))}
    st = cache["cell"] if cache is not None else xlstm_lib.slstm_state(b, hh, dh)
    r = {"i": p["r_i"], "f": p["r_f"], "z": p["r_z"], "o": p["r_o"]}
    y, new_st = xlstm_lib.slstm_scan(gx, r, st)       # (B,S,H,dh)
    y = y.reshape(b, s, d)
    from repro.models.common import rmsnorm
    y = rmsnorm(y, p["out_norm"].value)
    x = x + y
    # GeGLU FFN (proj factor 4/3)
    h2 = apply_norm(x, p["norm2"].value, cfg.norm)
    up = jnp.einsum("bsd,df->bsf", h2, p["ffn_up"].value)
    g, u = jnp.split(up, 2, axis=-1)
    x = x + jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u, p["ffn_down"].value)
    return x, jnp.zeros((), jnp.float32), {"conv": new_tail, "cell": new_st}


def _remat_policy(cfg: ModelConfig):
    """'full' -> recompute everything; 'dots' -> save dot outputs;
    'save_moe' -> recompute everything EXCEPT the routed-MoE output, whose
    recompute would repeat the dispatch/return all_to_alls (§Perf A4)."""
    if cfg.remat == "full":
        return None
    if cfg.remat == "save_moe":
        return jax.checkpoint_policies.save_only_these_names("moe_out")
    return jax.checkpoint_policies.checkpoint_dots


_LAYER_FNS = {
    "dense": _dense_layer, "moe": _dense_layer,
    "mla_dense": _mla_layer, "mla_moe": _mla_layer,
    "griffin": _griffin_layer,
    "mlstm": _mlstm_layer, "slstm": _slstm_layer,
}


def _layer_fn(kind):
    if kind.startswith("griffin_tail"):
        return _griffin_layer
    return _LAYER_FNS[kind]


def forward(params, tokens, cfg: ModelConfig, *, embeds=None,
            positions=None, caches=None, decode=False):
    """Full forward.  tokens (B,S) i32 (or ``embeds`` (B,S,d) for frontend
    stubs).  With ``decode=True``/caches, runs a cached single-token step.

    Returns (logits (B,S,V), aux_loss, new_caches).
    """
    if embeds is not None:
        x = embeds.astype(_dtype(cfg))
    else:
        x = params["embed"].value[tokens] * cfg.embed_scale
        x = x.astype(_dtype(cfg))
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = shard(x, ("pod", "data"), None, None)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    groups = layer_groups(cfg)
    for gi, (kind, count) in enumerate(groups):
        gp = params["groups"][gi]
        fn = _layer_fn(kind)
        cache_g = None if caches is None else caches[gi]
        if count == 1:
            call = lambda gp_, x_: fn(gp_, x_, positions, cfg, kind,
                                      cache=cache_g)
            if cfg.remat != "none" and not decode:
                call = jax.checkpoint(call, policy=_remat_policy(cfg),
                                      prevent_cse=False)
            x, aux, nc = call(gp, x)
            aux_total += aux
            new_caches.append(nc)
        else:
            def body(carry, xs):
                x, aux = carry
                lp, lc = xs
                x, a, nc = fn(lp, x, positions, cfg, kind, cache=lc)
                return (x, aux + a), nc

            body_fn = body
            if cfg.remat != "none" and not decode:
                body_fn = jax.checkpoint(body, policy=_remat_policy(cfg),
                                         prevent_cse=False)
            (x, aux_total), ncs = jax.lax.scan(
                body_fn, (x, aux_total), (gp, cache_g))
            new_caches.append(ncs)

    x = apply_norm(x, params["final_norm"].value, cfg.norm)
    head = (params["embed"].value.T if cfg.tie_embeddings
            else params["lm_head"].value)
    logits = jnp.einsum("bsd,dv->bsv", x, head) * cfg.logit_scale
    logits = shard(logits, ("pod", "data"), None, "model")
    return logits, aux_total, new_caches


def loss_fn(params, batch, cfg: ModelConfig, aux_weight=0.01):
    logits, aux, _ = forward(params, batch.get("tokens"), cfg,
                             embeds=batch.get("embeds"))
    ce = cross_entropy_loss(logits, batch["labels"], batch["mask"])
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------- decode

def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Zeroed cache pytree matching ``forward``'s caches argument.

    Sliding-window attention uses ring caches of window size; recurrent
    blocks keep O(1) state; full attention allocates (B, max_len, ...).
    """
    dt = _dtype(cfg)
    hk, dh = cfg.num_kv_heads, cfg.head_dim_

    def attn_cache(window):
        t = min(window, max_len) if window else max_len
        return {"k": jnp.zeros((batch, t, hk, dh), dt),
                "v": jnp.zeros((batch, t, hk, dh), dt),
                "kv_pos": jnp.full((batch, t), -1, jnp.int32)}

    def one(kind):
        if kind in ("dense", "moe"):
            return attn_cache(cfg.window if cfg.attention == "sliding" else None)
        if kind in ("mla_dense", "mla_moe"):
            return {"ckv": jnp.zeros((batch, max_len, cfg.mla.kv_lora_rank), dt),
                    "kr": jnp.zeros((batch, max_len, cfg.mla.qk_rope_head_dim), dt),
                    "kv_pos": jnp.full((batch, max_len), -1, jnp.int32)}
        if kind == "griffin" or kind.startswith("griffin_tail"):
            sub = cfg.recurrent.pattern if kind == "griffin" \
                else (kind.removeprefix("griffin_tail_"),)
            return [rec_lib.init_state(batch, cfg.d_model, cfg.recurrent, dt)
                    if s == "rglru" else attn_cache(cfg.recurrent.local_window)
                    for s in sub]
        if kind == "mlstm":
            di = int(cfg.xlstm.proj_factor * cfg.d_model)
            hh = cfg.xlstm.num_heads
            return {"conv": jnp.zeros((batch, 3, di), dt),
                    "cell": xlstm_lib.mlstm_state(batch, hh, di // hh, di // hh)}
        if kind == "slstm":
            hh = cfg.xlstm.num_heads
            return {"conv": jnp.zeros((batch, 3, cfg.d_model), dt),
                    "cell": xlstm_lib.slstm_state(batch, hh, cfg.d_model // hh)}
        raise ValueError(kind)

    caches = []
    for kind, count in layer_groups(cfg):
        c = one(kind)
        if count > 1:
            c = jax.tree.map(lambda a: jnp.broadcast_to(
                a[None], (count,) + a.shape).copy(), c)
        caches.append(c)
    return caches


def decode_step(params, caches, tokens, pos, cfg: ModelConfig):
    """One token for every sequence.  tokens (B,1), pos () i32 current
    position.  Returns (logits (B,1,V), new_caches)."""
    b = tokens.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos)[None, None], (b, 1))
    logits, _, new_caches = forward(params, tokens, cfg,
                                    positions=positions, caches=caches,
                                    decode=True)
    return logits, new_caches
