from repro.models import (attention, common, encdec, mla, moe, recurrent,
                          registry, transformer, xlstm)

__all__ = ["attention", "common", "encdec", "mla", "moe", "recurrent",
           "registry", "transformer", "xlstm"]
