"""Multi-head Latent Attention (DeepSeek-V3).

Keys/values are stored as a single low-rank latent ``c_kv`` (kv_lora_rank
wide, 512 for DSv3) plus a tiny shared RoPE key — so the decode KV cache is
(512 + 64) floats/token instead of 2 * H * Dh = 32768: a 56x cache shrink,
which is what makes the decode_32k roofline memory term move.

Train/prefill uses the expanded form (chunked flash attention); decode uses
the *absorbed* form (q projected through W_uk into latent space, attention
performed directly against the latent cache, output re-expanded via W_uv).
Tests assert absorbed-decode == expanded attention at the last position.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models import attention as attn_lib
from repro.models.common import apply_rope, param, rmsnorm, split_keys


def init_mla(key, d_model: int, num_heads: int, mla: MLAConfig, dtype):
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    ks = split_keys(key, 9)
    return {
        "w_dq": param(ks[0], (d_model, mla.q_lora_rank), ("embed", "q_lora"), dtype=dtype),
        "q_norm": param(ks[1], (mla.q_lora_rank,), ("q_lora",), init="zeros"),
        "w_uq": param(ks[2], (mla.q_lora_rank, num_heads, dn + dr),
                      ("q_lora", "heads", "head_dim"), dtype=dtype),
        "w_dkv": param(ks[3], (d_model, mla.kv_lora_rank), ("embed", "kv_lora"), dtype=dtype),
        "kv_norm": param(ks[4], (mla.kv_lora_rank,), ("kv_lora",), init="zeros"),
        "w_kr": param(ks[5], (d_model, dr), ("embed", "head_dim"), dtype=dtype),
        "w_uk": param(ks[6], (mla.kv_lora_rank, num_heads, dn),
                      ("kv_lora", "heads", "head_dim"), dtype=dtype),
        "w_uv": param(ks[7], (mla.kv_lora_rank, num_heads, dv),
                      ("kv_lora", "heads", "head_dim"), dtype=dtype),
        "w_o": param(ks[8], (num_heads, dv, d_model),
                     ("heads", "head_dim", "embed"), dtype=dtype),
    }


def _queries(p, x, positions, mla: MLAConfig, rope_theta):
    dn, dr = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    cq = rmsnorm(jnp.einsum("bsd,dq->bsq", x, p["w_dq"].value),
                 p["q_norm"].value)
    q = jnp.einsum("bsq,qhe->bshe", cq, p["w_uq"].value)      # (B,S,H,dn+dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, rope_theta)
    return qn, qr


def _latents(p, x, positions, mla: MLAConfig, rope_theta):
    ckv = rmsnorm(jnp.einsum("bsd,dc->bsc", x, p["w_dkv"].value),
                  p["kv_norm"].value)                          # (B,S,C)
    kr = jnp.einsum("bsd,de->bse", x, p["w_kr"].value)[:, :, None, :]
    kr = apply_rope(kr, positions, rope_theta)                 # (B,S,1,dr)
    return ckv, kr


def mla_attention(p, x, positions, mla: MLAConfig, rope_theta=10_000.0,
                  q_chunk=512, kv_chunk=1024, dense_below=1024):
    """Expanded-form MLA for train/prefill.  x (B,S,d) -> (B,S,d)."""
    h = p["w_uk"].value.shape[1]
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    qn, qr = _queries(p, x, positions, mla, rope_theta)
    ckv, kr = _latents(p, x, positions, mla, rope_theta)
    kn = jnp.einsum("bsc,chn->bshn", ckv, p["w_uk"].value)     # (B,S,H,dn)
    v = jnp.einsum("bsc,chv->bshv", ckv, p["w_uv"].value)      # (B,S,H,dv)
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, kn.shape[:3] + (dr,))], axis=-1)
    scale = (dn + dr) ** -0.5
    o = attn_lib.attention(q, k, v, causal=True, scale=scale,
                           q_chunk=q_chunk, kv_chunk=kv_chunk,
                           dense_below=dense_below)
    return jnp.einsum("bshv,hvd->bsd", o, p["w_o"].value)


def mla_decode(p, x, ckv_cache, kr_cache, kv_positions, pos, mla: MLAConfig,
               rope_theta=10_000.0):
    """Absorbed-form single-token decode.

    x (B,1,d); ckv_cache (B,T,C) (normalized latents, current token already
    written); kr_cache (B,T,dr) (roped); returns (B,1,d).
    """
    dn, dr = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    positions = jnp.asarray(pos)[None, None] if jnp.asarray(pos).ndim == 0 \
        else jnp.asarray(pos)[:, None]
    qn, qr = _queries(p, x, positions, mla, rope_theta)        # (B,1,H,*)
    # absorb W_uk: q_lat (B,1,H,C) — attention runs in latent space
    q_lat = jnp.einsum("bshn,chn->bshc", qn.astype(jnp.float32),
                       p["w_uk"].value.astype(jnp.float32))
    s_lat = jnp.einsum("bshc,btc->bhst", q_lat,
                       ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bshe,bte->bhst", qr.astype(jnp.float32),
                        kr_cache.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    s = (s_lat + s_rope) * scale                               # (B,H,1,T)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (x.shape[0],))
    valid = (kv_positions >= 0) & (kv_positions <= pos_b[:, None])
    s = jnp.where(valid[:, None, None, :], s, attn_lib.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btc->bshc", pr, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bshc,chv->bshv", ctx,
                   p["w_uv"].value.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bshv,hvd->bsd", o, p["w_o"].value)
