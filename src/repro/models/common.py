"""Model substrate: boxed params with logical sharding axes, norms, RoPE.

Every parameter is created through :func:`param`, which attaches a tuple of
*logical axis names* (``'embed'``, ``'heads'``, ``'ff'``, ...) as pytree
aux-data.  ``unbox`` splits a boxed tree into (values, axes); axes map to mesh
axes through per-arch sharding rules (distributed/sharding.py).  Because axes
ride in aux-data, ``jax.eval_shape`` over an init function yields abstract
params *with* their sharding — that is what the multi-pod dry-run consumes
(no parameter is ever materialized for the full-size configs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class Box:
    """A parameter leaf + its logical sharding axes (aux-data)."""
    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Box(shape={shape}, axes={self.axes})"


def _is_box(x):
    return isinstance(x, Box)


def unbox(tree):
    """Boxed tree -> (param values, logical axes tree)."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=_is_box)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=_is_box)
    return values, axes


def boxed_like(values, axes):
    """Re-attach axes to a value tree (inverse of unbox)."""
    return jax.tree.map(Box, values, axes,
                        is_leaf=lambda x: x is None)


def stack_axes(boxed_tree, axis_name: str = "layers"):
    """Prepend a logical axis to every Box after a vmap-stacking init.

    vmap adds the leading (layer) dim to Box *values* but aux-data axes
    pass through unchanged — without this fix-up every stacked tensor's
    sharding spec is off by one dimension.
    """
    return jax.tree.map(lambda b: Box(b.value, (axis_name,) + b.axes),
                        boxed_tree, is_leaf=_is_box)


def param(key, shape, axes, dtype=jnp.float32, init="normal", scale=None):
    """Create one boxed parameter.

    init: 'normal' (trunc-normal, fan-in scaled unless ``scale``), 'zeros',
    'ones', 'embed' (normal 1.0 scaled by ``scale`` or 0.02).
    """
    assert len(shape) == len(axes), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            scale = fan_in ** -0.5 if init == "normal" else 0.02
        v = (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
             * scale).astype(dtype)
    return Box(v, axes)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ----------------------------- norms -----------------------------

def rmsnorm(x, weight, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layernorm(x, weight, eps=1e-5):
    """Bias-free LayerNorm (command-r style)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x - jnp.mean(x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def norm_init(key, d, kind):
    if kind == "rmsnorm":
        return param(key, (d,), ("embed",), init="zeros")
    return param(key, (d,), ("embed",), init="ones")


def apply_norm(x, w, kind):
    return rmsnorm(x, w) if kind == "rmsnorm" else layernorm(x, w)


# ----------------------------- RoPE -----------------------------

def rope_freqs(head_dim, theta=10_000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10_000.0):
    """x: (..., S, H, Dh) with positions (..., S) — interleaved-pair RoPE."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------- misc -----------------------------

def shard(x, *mesh_axes):
    """Best-effort activation sharding constraint by positional mesh axes.

    ``mesh_axes`` entries are mesh-axis names (or None/tuples); ignored when
    no mesh is active so model code runs identically on a single device.
    """
    from jax.sharding import PartitionSpec as P
    try:
        env_mesh = jax.sharding.get_abstract_mesh()
        if env_mesh is None or not env_mesh.shape:
            return x
        valid = set(env_mesh.axis_names)
        fixed = []
        for a in mesh_axes:
            if a is None:
                fixed.append(None)
            elif isinstance(a, tuple):
                names = tuple(n for n in a if n in valid)
                fixed.append(names if names else None)
            else:
                fixed.append(a if a in valid else None)
        return jax.lax.with_sharding_constraint(x, P(*fixed))
    except Exception:
        return x


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: silu(x @ Wg) * (x @ Wu) @ Wd."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def cross_entropy_loss(logits, labels, mask):
    """Mean token cross-entropy in f32. logits (..., V), labels (...) i32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
