"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query, and tests/benches must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(shape=None, axes=("data",)):
    """Whatever devices the current process actually has (tests/examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n,)
    return make_mesh(shape, axes)


def flat_axis_names(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
