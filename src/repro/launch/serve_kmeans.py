"""Serving harness for the clustering tier (mirrors ``launch/serve.py``).

Stands up a :class:`repro.core.serve.NearestCentroidServer` over centroids
from a quick synthetic solve, then drives a steady-state dispatch loop:
random-sized query batches arrive, coalesce into bucketed kernel launches,
and a background mini-batch refresh periodically folds a sampled (drifting)
traffic batch into the served centroids.  Prints p50/p99 dispatch latency,
QPS, the jit-trace count per bucket, and the refresh SSE series.

``--smoke`` shrinks everything to a seconds-scale CI check —
``python -m repro.launch.serve_kmeans --smoke`` is the serve-smoke CI job.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KMeansParams, kmeans
from repro.core.serve import BucketPolicy, NearestCentroidServer


def make_stream(key, n: int, d: int, k: int, *, drift: float = 0.0):
    """Synthetic traffic: points around k cluster centers, optionally
    drifted — (points (n,d), true centers (k,d))."""
    kc, kp, ka = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (k, d)) * 4.0 + drift
    which = jax.random.randint(ka, (n,), 0, k)
    pts = centers[which] + jax.random.normal(kp, (n, d))
    return pts, centers


def serve_loop(server: NearestCentroidServer, key, *, requests: int,
               max_request: int, d: int, refresh_every: int = 0,
               refresh_rows: int = 256, drift_step: float = 0.0,
               quiet: bool = False):
    """Steady-state loop: submit random-sized batches, dispatch, refresh.

    Returns ``(latencies_s (list, one per dispatch), served_rows)``.  Each
    dispatch is timed to completion (``block_until_ready``), so latencies
    include the coalesce + pad + kernel + unpack path a caller would see.
    """
    latencies, served = [], 0
    drift = 0.0
    for i in range(requests):
        key, ks, kq = jax.random.split(key, 3)
        n = int(jax.random.randint(ks, (), 1, max_request + 1))
        q, _ = make_stream(kq, n, d, server.centroids.shape[0], drift=drift)
        t = server.submit(q)
        t0 = time.perf_counter()
        done = server.step()
        labels, _ = server.result(t)
        jax.block_until_ready(labels)
        latencies.append(time.perf_counter() - t0)
        served += n
        assert done and t in done
        if refresh_every and (i + 1) % refresh_every == 0:
            drift += drift_step
            key, kr = jax.random.split(key)
            batch, _ = make_stream(kr, refresh_rows, d,
                                   server.centroids.shape[0], drift=drift)
            sse = server.refresh(batch)
            if not quiet:
                print(f"  refresh @{i + 1}: batch sse {float(sse):.1f} "
                      f"(drift {drift:.2f})")
    return latencies, served


def main(argv=None) -> NearestCentroidServer:
    ap = argparse.ArgumentParser(
        description="nearest-centroid serving endpoint (clustering tier)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sizes for CI")
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--max-request", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=8)
    ap.add_argument("--max-bucket", type=int, default=512)
    ap.add_argument("--refresh-every", type=int, default=50,
                    help="dispatches between mini-batch refreshes (0: off)")
    ap.add_argument("--refresh-rows", type=int, default=256)
    ap.add_argument("--backend", default="fused",
                    help="refresh engine (any registered backend)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.k, args.dim = 8, 8
        args.requests, args.max_request = 12, 24
        args.max_bucket, args.refresh_every = 32, 6
        args.refresh_rows = 64

    key = jax.random.key(args.seed)
    key, kd = jax.random.split(key)
    data, _ = make_stream(kd, max(64, 8 * args.k), args.dim, args.k)
    res = kmeans(data, data[:args.k], params=KMeansParams(max_iters=10))
    # seed the refresh counts from the solve's cluster sizes: large counts
    # mean small learning rates, so a trusted solve drifts slowly
    from repro.kernels import ref
    labels, _ = ref.assign_ref(data, res.centroids)
    seed_counts = jnp.asarray(
        np.bincount(np.asarray(labels), minlength=args.k), jnp.float32)

    server = NearestCentroidServer(
        res.centroids, seed_counts,
        policy=BucketPolicy(min_bucket=args.min_bucket,
                            max_bucket=args.max_bucket),
        refresh_backend=args.backend)

    t0 = time.perf_counter()
    lats, served = serve_loop(
        server, key, requests=args.requests, max_request=args.max_request,
        d=args.dim, refresh_every=args.refresh_every,
        refresh_rows=args.refresh_rows, drift_step=0.25)
    wall = time.perf_counter() - t0

    lat_ms = np.asarray(lats) * 1e3
    print(f"served {served} rows / {args.requests} requests in {wall:.2f}s "
          f"({served / wall:.0f} rows/s)")
    print(f"dispatch latency p50 {np.percentile(lat_ms, 50):.2f}ms "
          f"p99 {np.percentile(lat_ms, 99):.2f}ms")
    print(f"jit traces per bucket: {dict(sorted(server.trace_counts.items()))}")
    if server.refresh_sse:
        print("refresh sse series:",
              [round(s, 1) for s in server.refresh_sse])
    assert all(v == 1 for v in server.trace_counts.values()), \
        "jit cache exceeded one entry per bucket"
    return server


if __name__ == "__main__":
    main()
