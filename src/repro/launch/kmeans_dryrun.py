import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dry-run of the paper's technique itself at production scale.

Lowers three programs on the 16x16 (and optionally 2x16x16) mesh over a
67M-point, 64-d, K=1024 clustering problem — a realistic embedding-table
clustering job (e.g. VQ codebook training for chameleon):

  * pkmeans_step   — the baseline: ONE Lloyd iteration with its global
    psum (the per-iteration "MapReduce job").  Total cost = iters x this.
  * ipkmeans_s1    — k-d tree partition + labeling + packing (O(log n)
    sort rounds; the one-off preprocessing).
  * ipkmeans_s2s3  — M=4096 independent Lloyd solvers to convergence under
    shard_map + merge.  The paper's claim is structural: ZERO collectives
    inside the solver loop — asserted from the compiled HLO.

Writes experiments/dryrun/kmeans__<stage>__<mesh>.json in the same format
as the LM cells, so §Roofline includes the paper's own technique.
"""
import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.core import IPKMeansConfig, KMeansParams, kdtree
from repro.core.kmeans import KMeansResult, kmeans_batched
from repro.core.merge import min_asse_merge
from repro.core.pkmeans import _local_stats
from repro.launch.dryrun import (HBM_BW, ICI_BW, OUT_DIR, PEAK_FLOPS,
                                 collective_bytes)
from repro.launch.mesh import make_production_mesh

# production clustering problem (embedding-table scale)
N, D, K, M = 1 << 26, 64, 1024, 4096
MAX_ITERS = 50


def count_collectives_in_while_bodies(hlo: str) -> int:
    """Collective ops appearing inside any while-loop body computation."""
    import re as _re
    body_names = set()
    for m in _re.finditer(r"body=%?([\w.\-]+)", hlo):
        body_names.add(m.group(1))
    count = 0
    current = None
    for line in hlo.splitlines():
        m = _re.match(r"\s*%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{", line)
        if m:
            current = m.group(1)
            continue
        if line.strip() == "}":
            current = None
            continue
        if current in body_names and any(
                op in line for op in ("all-reduce", "all-gather",
                                      "reduce-scatter", "all-to-all",
                                      "collective-permute")):
            count += 1
    return count


def _record(name, mesh_tag, lowered, compiled, extra=None):
    cost = compat.cost_analysis(compiled)
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    counts = coll.pop("_counts", {})
    total_coll = sum(coll.values())
    rec = {
        "arch": f"kmeans-{name}", "shape": f"n{N}_d{D}_k{K}_m{M}",
        "mesh": mesh_tag, "status": "ok",
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll, "collective_counts": counts,
        "roofline": {
            "compute_s": float(cost.get("flops", 0.0)) / PEAK_FLOPS,
            "memory_s": float(cost.get("bytes accessed", 0.0)) / HBM_BW,
            "collective_s": total_coll / ICI_BW,
        },
    }
    rec["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"),
        key=rec["roofline"].get)
    mem = compiled.memory_analysis()
    if mem is not None:
        for k_ in ("argument_size_in_bytes", "output_size_in_bytes",
                   "temp_size_in_bytes"):
            v = getattr(mem, k_, None)
            if v is not None:
                rec[k_] = int(v)
    if extra:
        rec.update(extra)
    return rec


def lower_all(multi_pod: bool, backend: str = "jnp",
              reseed_empty: bool = False, prune: str = "none",
              init_round: bool = False, pods: int = 0,
              reduce: str = "exact"):
    """Lower the dry-run cells.  ``backend`` names the Lloyd engine for
    pkmeans-iter and s2s3 (any name in the ``kernels.engine`` registry —
    'jnp' | 'pallas' | 'fused' | 'resident' | 'batched' | 'tuned');
    non-default backends skip the
    backend-independent S1 cells and write records suffixed ``__<backend>``
    so perf_variants can diff them against the jnp baselines.  With
    'resident', each S2 reducer whose subset fits VMEM lowers as ONE kernel
    launch per solve (the engine's feasibility guard decides — infeasible
    shapes lower the fused per-step loop instead); with 'batched', the whole
    per-device reducer stack lowers as one pipelined multi-group launch
    (same guard, vmap-of-solve fallback).  ``reseed_empty`` lowers the S2
    solvers with in-kernel farthest-point empty-cluster reseeding — the
    configuration that matches PKMeans quality end to end — and suffixes
    the records ``__reseed``; the whole-solve engines KEEP their kernels
    (the reseed runs inside the convergence loop).  ``prune="bounds"``
    lowers the S2 solvers with bound-gated block skipping in the kernel
    convergence loops (bit-for-bit-identical results — a pure perf knob)
    and suffixes the records ``__prune``.  ``init_round`` additionally
    lowers ONE k-means|| seeding round — the fused distance+min+sample
    sweep running per shard under ``shard_map`` with the candidate tile
    replicated and only the scalar potential psum crossing shards; total
    seeding cost = (rounds+1) x this cell plus the O(ell log n) host
    recluster."""
    # ``pods >= 2`` additionally lowers the CROSS-POD cells on a
    # (pods x devices) k-means pod mesh.  S2: the same M reducers with each
    # subset's points sharded over the slow DCN axis, so every Lloyd
    # iteration carries exactly ONE (sums, counts) reduction over the pod
    # axis — 'exact' f32 psum or 'int8ef' compressed all-gather per
    # ``reduce`` — and the record reports both the HLO's in-loop collective
    # count (now intentionally nonzero) and the modeled per-pod DCN bytes.
    # S1 (jnp backend only): the sharded histogram build + labeler + pod
    # a2a pack, with a hard check that the lowered reduction collectives
    # stay within 4x the O(R*256) histogram byte model — i.e. summaries
    # cross hosts, never the dataset.
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_tag = "x".join(map(str, mesh.devices.shape))
    file_tag = mesh_tag if backend == "jnp" else f"{mesh_tag}__{backend}"
    if reseed_empty:
        file_tag += "__reseed"
    if prune != "none":
        file_tag += "__prune"
    axes = tuple(mesh.axis_names)
    flat = P(axes)
    n_dev = 512 if multi_pod else 256
    results = []

    pts = jax.ShapeDtypeStruct((N, D), jnp.float32)
    init_c = jax.ShapeDtypeStruct((K, D), jnp.float32)
    shard_pts = NamedSharding(mesh, P(axes, None))
    repl = NamedSharding(mesh, P())

    # ---- PKMeans: one Lloyd iteration with its global psum ----
    def pk_step(points, centroids):
        def body(p, c):
            sums, counts, _ = _local_stats(p, c, None, backend)
            sums = jax.lax.psum(sums, axes)
            counts = jax.lax.psum(counts, axes)
            return jnp.where(counts[:, None] > 0,
                             sums / jnp.maximum(counts[:, None], 1.0), c)
        return shard_map(body, mesh=mesh, in_specs=(P(axes, None), P()),
                             out_specs=P(), check_vma=False)(points, centroids)

    t0 = time.time()
    low = jax.jit(pk_step, in_shardings=(shard_pts, repl)).lower(pts, init_c)
    comp = low.compile()
    rec = _record("pkmeans-iter", mesh_tag, low, comp,
                  {"compile_s": round(time.time() - t0, 1),
                   "note": "cost is PER Lloyd iteration; total = iters x this"})
    results.append(rec)

    # ---- IPKMeans S1: kd-tree partition + labels + pack ----
    depth = kdtree.required_depth(N, M)

    def make_s1(builder, pack_mode="scatter"):
        def s1(points, key):
            part = kdtree.partition_dataset(points, key, M, leaf_capacity=M,
                                            strategy="kd_axis",
                                            builder=builder)
            if pack_mode == "a2a":
                return kdtree.pack_subsets_a2a(points, part.subset_ids, M,
                                               2 ** depth, mesh, axes)
            pack = (kdtree.pack_subsets_sorted if pack_mode == "sorted"
                    else kdtree.pack_subsets)
            return pack(points, part.subset_ids, M, 2 ** depth)
        return s1

    key_abs = jax.eval_shape(lambda: jax.random.key(0))
    # S1 has no Lloyd phase, so its cells are backend-independent — lower
    # them only for the jnp baseline (the slowest compiles of the sweep)
    s1_cells = () if backend != "jnp" else (
            ("sort", "scatter", "ipkmeans-s1",
             "one-off preprocessing: O(log n) sort rounds (paper-faithful)"),
            ("histogram", "scatter", "ipkmeans-s1-hist",
             "perf C1: radix-histogram exact medians, sort-free build"),
            ("histogram", "sorted", "ipkmeans-s1-opt",
             "perf C2: C1 + sort+reshape pack (kills dataset all-reduce)"),
            ("histogram", "a2a", "ipkmeans-s1-a2a",
             "perf C3: C1 + explicit shard_map all_to_all shuffle"))
    for builder, pack_mode, name, note in s1_cells:
        t0 = time.time()
        low = jax.jit(make_s1(builder, pack_mode),
                      in_shardings=(shard_pts, repl)).lower(pts, key_abs)
        comp = low.compile()
        rec = _record(name, mesh_tag, low, comp,
                      {"compile_s": round(time.time() - t0, 1),
                       "kd_depth": depth, "note": note})
        results.append(rec)

    # ---- IPKMeans S2+S3: M independent solvers, zero collectives ----
    sub_shape = jax.ShapeDtypeStruct((M, 2 ** depth, D), jnp.float32)
    msk_shape = jax.ShapeDtypeStruct((M, 2 ** depth), bool)
    shard_m = NamedSharding(mesh, P(axes, None, None))
    shard_mm = NamedSharding(mesh, P(axes, None))
    params = KMeansParams(max_iters=MAX_ITERS, backend=backend,
                          reseed_empty=reseed_empty, prune=prune)

    def s2s3(subsets, masks, init_centroids):
        def body(sub, msk):
            return kmeans_batched(sub, msk, init_centroids, params)
        spec = P(axes)
        res = shard_map(
            body, mesh=mesh, in_specs=(spec, spec),
            out_specs=KMeansResult(spec, spec, spec, spec, spec),
            check_vma=False)(subsets, masks)
        return min_asse_merge(res.centroids, res.asse)

    t0 = time.time()
    low = jax.jit(s2s3, in_shardings=(shard_m, shard_mm, repl)).lower(
        sub_shape, msk_shape, init_c)
    comp = low.compile()
    txt = comp.as_text()
    # the paper's structural claim: no collectives inside the Lloyd while
    # loop.  The merge gathers M*K centroids once at the end; check that
    # while-body computations are collective-free.
    loop_coll = count_collectives_in_while_bodies(txt)
    rec = _record("ipkmeans-s2s3", mesh_tag, low, comp,
                  {"compile_s": round(time.time() - t0, 1),
                   "collectives_in_solver_loop": loop_coll,
                   "note": "M=4096 reducers to convergence + min-ASSE merge"})
    results.append(rec)

    # ---- cross-pod S2: points sharded over the DCN axis ----
    if pods >= 2:
        from repro.core.io_model import dcn_reduce_bytes_ipkmeans
        from repro.core.ipkmeans import _s2_cross_pod_solve
        from repro.distributed.sharding import (KMEANS_DATA_AXIS,
                                                KMEANS_POD_AXIS,
                                                kmeans_pod_mesh, subset_specs)
        if n_dev % pods:
            raise ValueError(f"pods={pods} must divide {n_dev} devices")
        pmesh = kmeans_pod_mesh(pods, n_dev // pods)
        pmesh_tag = f"{pods}x{n_dev // pods}"
        cap = 2 ** depth + (-(2 ** depth) % pods)
        xcfg = IPKMeansConfig(num_clusters=K, num_subsets=M, reduce=reduce,
                              kmeans=params)
        sub_s, msk_s, out_s = subset_specs((KMEANS_DATA_AXIS,),
                                           KMEANS_POD_AXIS)

        def s2_xpod(subsets, masks, init_centroids):
            def body(sub, msk):
                c, _, asse, _, _ = _s2_cross_pod_solve(
                    sub, msk, init_centroids, xcfg, KMEANS_POD_AXIS)
                return c, asse
            c, asse = shard_map(
                body, mesh=pmesh, in_specs=(sub_s, msk_s),
                out_specs=(out_s, out_s), check_vma=False)(subsets, masks)
            return min_asse_merge(c, asse)

        xsub = jax.ShapeDtypeStruct((M, cap, D), jnp.float32)
        xmsk = jax.ShapeDtypeStruct((M, cap), bool)
        t0 = time.time()
        low = jax.jit(s2_xpod, in_shardings=(
            NamedSharding(pmesh, sub_s), NamedSharding(pmesh, msk_s),
            NamedSharding(pmesh, P()))).lower(xsub, xmsk, init_c)
        comp = low.compile()
        loop_coll = count_collectives_in_while_bodies(comp.as_text())
        rec = _record(f"ipkmeans-s2-xpod{pods}-{reduce}", pmesh_tag, low, comp,
                      {"compile_s": round(time.time() - t0, 1),
                       "pods": pods, "reduce": reduce,
                       "collectives_in_solver_loop": loop_coll,
                       "dcn_bytes_per_pod_modeled": dcn_reduce_bytes_ipkmeans(
                           M, K, D, MAX_ITERS, pods, reduce),
                       "note": f"cross-pod S2 ({reduce}): the in-loop "
                               f"collective IS the per-iteration DCN stats "
                               f"reduction (expected nonzero)"})
        results.append(rec)

        # ---- cross-pod S1: sharded histogram build + label + pod a2a ----
        # (backend-independent like the other S1 cells, so jnp-only)
        if backend == "jnp":
            from repro.core.io_model import s1_histogram_dcn_bytes
            from repro.distributed.sharding import s1_point_spec
            x_axes = (KMEANS_POD_AXIS, KMEANS_DATA_AXIS)

            def s1_xpod(points, key):
                part = kdtree.partition_dataset(
                    points, key, M, leaf_capacity=M, strategy="kd_axis",
                    builder="histogram", labeler="histogram",
                    mesh=pmesh, axis_names=x_axes)
                return kdtree.pack_subsets_a2a(
                    points, part.subset_ids, M, cap, pmesh,
                    (KMEANS_DATA_AXIS,), pod_axis=KMEANS_POD_AXIS)

            pt_spec = s1_point_spec((KMEANS_DATA_AXIS,), KMEANS_POD_AXIS)
            t0 = time.time()
            low = jax.jit(s1_xpod, in_shardings=(
                NamedSharding(pmesh, pt_spec),
                NamedSharding(pmesh, P()))).lower(pts, key_abs)
            comp = low.compile()
            coll = collective_bytes(comp.as_text())
            coll.pop("_counts", None)
            # the structural claim: every reduction collective carries
            # O(R*256) histogram summaries, never the dataset.  The a2a is
            # excluded — it's the pack, which moves each point exactly once
            # by construction.  Bound = 4x the full-mesh histogram model
            # (slack for GSPMD scheduling duplication), itself ~100x under
            # one dataset pass.
            summary_bytes = sum(v for op, v in coll.items()
                                if op != "all-to-all")
            bound = 4 * s1_histogram_dcn_bytes(depth, n_dev)
            if summary_bytes > bound:
                raise RuntimeError(
                    f"sharded S1 reduction collectives move {summary_bytes} "
                    f"bytes > 4x the histogram model ({bound}): a sort/"
                    f"gather-shaped lowering leaked into the sharded build")
            rec = _record(f"ipkmeans-s1-xpod{pods}", pmesh_tag, low, comp,
                          {"compile_s": round(time.time() - t0, 1),
                           "pods": pods, "kd_depth": depth,
                           "s1_summary_collective_bytes": summary_bytes,
                           "s1_histogram_model_bytes":
                               s1_histogram_dcn_bytes(depth, pods),
                           "note": "cross-pod S1: histogram build + label "
                                   "sharded over (pods, data); reductions "
                                   "bounded by the O(R*256) summary model"})
            results.append(rec)

    # ---- k-means|| init round: per-shard fused sweep + scalar psi psum ----
    if init_round:
        from repro.core.init import _make_sweep
        C = 2 * K                  # steady-state candidate tile (~ell = 2K)
        base_sweep = _make_sweep(
            "ref" if backend == "jnp" else "kernel", None, None, axes)

        def init_round_fn(points, cands, old_mind, u, w, psi_prev):
            def body(xs, oms, us, ws, cs, pps):
                valid = jnp.ones((C,), bool)
                mind, samp, psi = base_sweep(xs, cs, valid, oms, us, ws,
                                             pps, float(2 * K))
                return mind, samp, jax.lax.psum(psi, axes)

            run = shard_map(
                body, mesh=mesh, in_specs=(flat, flat, flat, flat, P(), P()),
                out_specs=(flat, flat, P()), check_vma=False)
            return run(points, old_mind, u, w, cands, psi_prev)

        vec = jax.ShapeDtypeStruct((N,), jnp.float32)
        cands_s = jax.ShapeDtypeStruct((C, D), jnp.float32)
        psi_s = jax.ShapeDtypeStruct((), jnp.float32)
        shard_vec = NamedSharding(mesh, flat)
        t0 = time.time()
        low = jax.jit(init_round_fn,
                      in_shardings=(shard_pts, repl, shard_vec, shard_vec,
                                    shard_vec, repl)).lower(
            pts, cands_s, vec, vec, vec, psi_s)
        comp = low.compile()
        rec = _record("ipkmeans-init-round", mesh_tag, low, comp,
                      {"compile_s": round(time.time() - t0, 1),
                       "candidate_tile": C,
                       "note": "ONE kmeans|| round: fused sweep per shard + "
                               "scalar psi psum; seeding = (rounds+1) x this"})
        results.append(rec)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    for rec in results:
        rec["backend"] = backend
        rec["reseed_empty"] = reseed_empty
        rec["prune"] = prune
        path = OUT_DIR / f"{rec['arch']}__{file_tag}.json"
        path.write_text(json.dumps(rec, indent=2))
        rf = rec["roofline"]
        print(f"{rec['arch']:22s} {mesh_tag}: dom={rf['dominant']:12s} "
              f"comp={rf['compute_s']:.3e} mem={rf['memory_s']:.3e} "
              f"coll={rf['collective_s']:.3e} "
              f"{rec.get('note', '')}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    from repro.kernels.engine import available
    ap.add_argument("--backend", default="jnp", choices=list(available()),
                    help="Lloyd engine lowered into the programs")
    ap.add_argument("--reseed-empty", action="store_true",
                    help="lower the S2 solvers with in-kernel empty-cluster "
                         "reseeding (the paper-pipeline quality knob; "
                         "whole-solve engines keep their kernels)")
    ap.add_argument("--prune", default="none", choices=["none", "bounds"],
                    help="lower the S2 solvers with bound-gated block "
                         "skipping in the kernel convergence loops "
                         "(bit-for-bit-identical results — a pure perf knob)")
    ap.add_argument("--init", action="store_true",
                    help="also lower ONE k-means|| seeding round: the fused "
                         "distance+min+sample sweep per shard plus the "
                         "scalar potential psum (total seeding = "
                         "(rounds+1) x this cell)")
    ap.add_argument("--pods", type=int, default=0,
                    help="also lower the CROSS-POD cells on a "
                         "(pods x devices) k-means pod mesh: the S2 cell "
                         "(each subset's points shard over the slow DCN "
                         "axis; one (sums, counts) reduction per Lloyd "
                         "iteration) and the S1 cell (sharded histogram "
                         "build + label + pod a2a pack, reduction bytes "
                         "checked against the O(R*256) summary model)")
    ap.add_argument("--reduce", default="exact", choices=["exact", "int8ef"],
                    help="cross-pod stats reduction for the --pods cell: "
                         "f32 psum or int8 error-feedback compression")
    args = ap.parse_args()
    lower_all(args.multi_pod, backend=args.backend,
              reseed_empty=args.reseed_empty, prune=args.prune,
              init_round=args.init, pods=args.pods, reduce=args.reduce)


if __name__ == "__main__":
    main()
