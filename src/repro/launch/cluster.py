"""Cluster launch entrypoint for real multi-host TPU fleets.

On a v5e pod each host runs:

    python -m repro.launch.cluster --coordinator <host0>:8476 \
        --num-hosts 64 --host-id $TPU_WORKER_ID -- \
        train --arch deepseek-v3-671b --shape train_4k --steps 10000

Responsibilities per host:
  * jax.distributed.initialize (GCE metadata autodetected when flags absent)
  * build the production mesh over the global device set
  * wrap the train loop with the fault-tolerance runtime: heartbeats to the
    coordinator, checkpoint-on-signal, restore-on-restart
  * on membership change (coordinator generation bump): rebuild mesh from
    survivors, reshard via the last committed checkpoint, resume

This module is exercised on CPU via --dry (single process pretending to be
N hosts) in tests; on real fleets it is the supervisor systemd/k8s target.
"""
from __future__ import annotations

import argparse
import os
import sys


def initialize_distributed(coordinator: str | None, num_hosts: int,
                           host_id: int):
    import jax
    if num_hosts > 1:
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_hosts,
            process_id=host_id)
    return jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int,
                    default=int(os.environ.get("REPRO_NUM_HOSTS", "1")))
    ap.add_argument("--host-id", type=int,
                    default=int(os.environ.get("TPU_WORKER_ID", "0")))
    ap.add_argument("--dry", action="store_true",
                    help="single-process protocol walk-through (CPU)")
    ap.add_argument("command", choices=["train", "serve", "dryrun"])
    ap.add_argument("rest", nargs=argparse.REMAINDER)
    args = ap.parse_args(argv)

    if not args.dry:
        initialize_distributed(args.coordinator, args.num_hosts,
                               args.host_id)

    if args.command == "train":
        from repro.launch.train import main as train_main
        sys.argv = ["train"] + args.rest
        train_main()
    elif args.command == "serve":
        from repro.launch.serve import main as serve_main
        sys.argv = ["serve"] + args.rest
        serve_main()
    else:
        from repro.launch.dryrun import main as dryrun_main
        sys.argv = ["dryrun"] + args.rest
        dryrun_main()


if __name__ == "__main__":
    main()
