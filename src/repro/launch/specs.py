"""Abstract input stand-ins (ShapeDtypeStruct) for every (arch x shape) cell.

The same pattern shannon/kernels uses: weak-type-correct, shardable, zero
device allocation — what ``jit.lower`` consumes in the dry-run.  Modality
frontends are stubs: audio supplies precomputed frame embeddings at a 2:1
frame:token ratio cap (seq capped at 4096 frames for enc-dec cells).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if cfg.is_encdec or cfg.frontend == "audio_frames":
        enc_len = min(s, 4096)
        batch["embeds"] = jax.ShapeDtypeStruct(
            (b, enc_len, cfg.d_model), jnp.bfloat16 if cfg.dtype == "bfloat16"
            else jnp.float32)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        # encoder consumes frames; decoder prefills tokens
        return {"embeds": jax.ShapeDtypeStruct(
                    (b, min(s, 4096), cfg.d_model),
                    jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(tokens (B,1), pos ()) — caches are built separately (abstract)."""
    b = shape.global_batch
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def abstract_decode_caches(cfg: ModelConfig, shape: ShapeSpec):
    from repro.models import registry
    b, s = shape.global_batch, shape.seq_len
    enc_len = min(s, 4096) if cfg.is_encdec else 0
    return jax.eval_shape(
        lambda: registry.init_decode_caches(cfg, b, s, enc_len))
