"""Training driver: step builder + CLI loop with checkpoint/restart.

``make_train_step`` is what the dry-run lowers for train shapes: loss +
backward + AdamW, params/opt-state donated, gradients reduced implicitly by
GSPMD (hierarchical on the multi-pod mesh: reduce-scatter in-pod over 'data',
all-reduce across 'pod').
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import SHAPES, get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models import registry
from repro.optim import schedules


def make_train_step(cfg, adamw_cfg: optim.AdamWConfig | None = None,
                    schedule=None):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    adamw_cfg = adamw_cfg or optim.AdamWConfig()
    schedule = schedule or functools.partial(
        schedules.cosine, peak_lr=3e-4, warmup=100, total=10_000)
    lf = registry.loss_fn(cfg)

    def train_step(params, opt_state, batch, step):
        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)
        lr = schedule(step)
        params, opt_state, gnorm = optim.update(grads, opt_state, params,
                                                lr, adamw_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **aux}
        return params, opt_state, metrics

    return train_step


def train_loop(cfg, *, steps: int, global_batch: int, seq_len: int,
               seed: int = 0, ckpt_dir: str | None = None,
               ckpt_every: int = 100, log_every: int = 10,
               adamw_cfg: optim.AdamWConfig | None = None,
               schedule=None,
               resume: bool = True):
    """Single-host training loop with checkpoint/restart (used by the
    end-to-end example and the fault-tolerance tests)."""
    from repro.checkpoint import manager as ckpt

    pipe = TokenPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                        global_batch=global_batch,
                                        seq_len=seq_len, seed=seed))
    params = registry.init_params(jax.random.key(seed), cfg)
    adamw_cfg = adamw_cfg or optim.AdamWConfig()
    opt_state = optim.init(params, adamw_cfg)
    start_step = 0
    if ckpt_dir and resume:
        restored = ckpt.restore_latest(ckpt_dir, (params, opt_state))
        if restored is not None:
            start_step, (params, opt_state) = restored
            print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, adamw_cfg, schedule),
                      donate_argnums=(0, 1))
    writer = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    history = []
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        if cfg.is_encdec or cfg.frontend == "audio_frames":
            batch["embeds"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(seed + 7), step),
                (global_batch, min(seq_len, 128), cfg.d_model), jnp.float32)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
            print(f"[train] step={step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={time.time()-t0:.2f}s", flush=True)
        if writer and ckpt_every and (step + 1) % ckpt_every == 0:
            writer.save(step + 1, (params, opt_state))
    if writer:
        writer.save(steps, (params, opt_state))
        writer.wait()
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = SHAPES[args.shape]
    gb = args.global_batch or (8 if args.smoke else shape.global_batch)
    sl = args.seq_len or (128 if args.smoke else shape.seq_len)
    train_loop(cfg, steps=args.steps, global_batch=gb, seq_len=sl,
               ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
