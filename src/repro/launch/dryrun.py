import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA_FLAGS line above runs before any
other import, because jax locks the device count on first init).  For each
cell it:

  1. builds the production mesh (16x16, or 2x16x16 with --multi-pod),
  2. builds abstract params / optimizer state / inputs (ShapeDtypeStruct —
     nothing is allocated),
  3. ``jit(step).lower(...).compile()`` with full in/out shardings,
  4. records cost_analysis (FLOPs, bytes), memory_analysis (per-device
     bytes) and the collective-bytes tally parsed from the optimized HLO,
  5. writes experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.distributed import sharding as shlib
from repro.launch import specs as speclib
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.launch.train import make_train_step
from repro.models import registry

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# v5e hardware constants (per chip) for the roofline terms
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 2 * 50e9            # ~2 links' worth of effective ring bandwidth

_COLL_RE = re.compile(
    r"(\w+\[[^\]]*\])[\w\s,()\{\}]*?=\s*(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|s32|u32|s64|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8}


def _shape_bytes(txt: str) -> int:
    m = _SHAPE_RE.match(txt)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _parse_computations(hlo_text: str):
    """{computation_name: [op lines]} from optimized HLO text."""
    comps: dict[str, list[str]] = {}
    current = None
    for raw in hlo_text.splitlines():
        if not raw.startswith(" ") and "{" in raw and "->" in raw:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", raw.strip())
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if raw.startswith("}"):
            current = None
            continue
        if current is not None and raw.strip():
            comps[current].append(raw.strip())
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Scan loops compare an induction var against one integer constant;
    dynamic (convergence) loops have compound conditions -> count once."""
    compares = [l for l in cond_lines if " compare(" in l]
    if len(compares) != 1 or any(" and(" in l for l in cond_lines):
        return 1
    consts = []
    for l in cond_lines:
        m = re.search(r"constant\((\d+)\)", l)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts and max(consts) <= 1_000_000 else 1


def collective_bytes(hlo_text: str) -> dict:
    """Effective collective bytes: per-op result bytes, multiplied by the
    trip counts of enclosing (scan-style) while loops via the call graph."""
    comps = _parse_computations(hlo_text)
    entry = None
    for raw in hlo_text.splitlines():
        if raw.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", raw)
            entry = m.group(1) if m else None
    if entry is None or entry not in comps:        # fallback: flat scan
        entry = max(comps, key=lambda c: len(comps[c]), default=None)

    out: dict[str, int] = {}
    count: dict[str, int] = {}

    def visit(comp: str, mult: int, seen: tuple):
        if comp not in comps or comp in seen:
            return
        for line in comps[comp]:
            m = re.match(
                r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|"
                r"(?:\w+\[[^\]]*\][^\s]*))\s+([\w\-]+)", line)
            op = m.group(2) if m else ""
            if op in _COLL_OPS:
                nbytes = sum(_shape_bytes(s) for s in
                             re.findall(r"\w+\[[\d,]*\]", m.group(1)))
                out[op] = out.get(op, 0) + nbytes * mult
                count[op] = count.get(op, 0) + 1
                continue
            wm = re.search(r"while\(.*?body=%?([\w.\-]+)", line)
            if wm:
                # XLA annotates statically-counted loops (scan) with
                # known_trip_count; dynamic (convergence) loops lack it and
                # count once (flagged in EXPERIMENTS.md methodology)
                tm = re.search(r'known_trip_count[^\d]*(\d+)', line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    cm = re.search(r"condition=%?([\w.\-]+)", line)
                    trips = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                visit(wm.group(1), mult * max(trips, 1), seen + (comp,))
                continue
            for key in ("to_apply=", "calls="):
                km = re.search(key + r"%?([\w.\-]+)", line)
                if km and km.group(1) in comps:
                    visit(km.group(1), mult, seen + (comp,))
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                for b in bm.group(1).split(","):
                    visit(b.strip().lstrip("%"), mult, seen + (comp,))

    visit(entry, 1, ())
    out["_counts"] = count
    return out


def _mesh_cells(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               include_optimizer: bool = True, cfg=None, overrides=None,
               fsdp: bool | None = None, layout: str = "train"):
    """Lower+compile one cell; returns the result record dict."""
    cfg = cfg or get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = _mesh_cells(mesh)
    boxed = registry.abstract_params(cfg)
    p_shard = shlib.param_shardings(boxed, cfg, mesh, fsdp=fsdp,
                                    layout=layout)
    record = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(map(str, mesh.devices.shape)),
              "n_devices": n_dev, "status": "ok"}
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            adamw_cfg = optim.AdamWConfig(
                state_dtype="bfloat16" if cfg.param_count() > 5e10
                else "float32")
            opt_abstract = jax.eval_shape(
                lambda p: optim.init(p, adamw_cfg), boxed)
            # optimizer state mirrors param shardings (ZeRO via FSDP rules)
            o_shard = optim.AdamWState(
                count=NamedSharding(mesh, P()), m=p_shard, v=p_shard)
            batch_abs = speclib.train_batch_specs(cfg, shape)
            b_shard = shlib.batch_shardings(batch_abs, mesh)
            step = make_train_step(cfg, adamw_cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard,
                              NamedSharding(mesh, P())),
                donate_argnums=(0, 1))
            lowered = jitted.lower(boxed, opt_abstract, batch_abs,
                                   jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            batch_abs = speclib.prefill_specs(cfg, shape)
            b_shard = shlib.batch_shardings(batch_abs, mesh)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(boxed, batch_abs)
        else:  # decode
            caches_abs = speclib.abstract_decode_caches(cfg, shape)
            c_shard = shlib.cache_shardings(caches_abs, cfg, mesh,
                                            shape.global_batch)
            dec = speclib.decode_specs(cfg, shape)
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard,
                              shlib.batch_shardings(
                                  {"t": dec["tokens"]}, mesh)["t"],
                              NamedSharding(mesh, P())),
                donate_argnums=(1,))
            lowered = jitted.lower(boxed, caches_abs, dec["tokens"],
                                   dec["pos"])

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

    cost = compat.cost_analysis(compiled)
    record["flops"] = float(cost.get("flops", 0.0))
    record["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                record[k] = int(v)
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    record["collective_bytes"] = {k: v for k, v in coll.items()
                                  if k != "_counts"}
    record["collective_counts"] = coll.get("_counts", {})
    record["hlo_chars"] = len(txt)

    # roofline terms (seconds) — cost_analysis flops are whole-program,
    # executed per device under SPMD: per-device flops = flops (XLA reports
    # the per-module count after partitioning)
    total_coll = sum(v for k, v in record["collective_bytes"].items())
    record["roofline"] = {
        "compute_s": record["flops"] / PEAK_FLOPS,
        "memory_s": record["bytes_accessed"] / HBM_BW,
        "collective_s": total_coll / ICI_BW,
    }
    dom = max(record["roofline"], key=record["roofline"].get)
    record["roofline"]["dominant"] = dom

    # model-level FLOPs for the usefulness ratio
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else (shape.seq_len if shape.kind == "prefill" else 1))
    mult = 6 if shape.kind == "train" else 2
    record["model_flops_global"] = float(mult * n_active * tokens)
    record["model_flops_per_device"] = record["model_flops_global"] / n_dev
    record["params"] = int(n_params)
    record["active_params"] = int(n_active)
    return record


def run_cell(arch, shape_name, multi_pod, force=False, out_dir=OUT_DIR,
             tag=""):
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}{tag}.json"
    path = out_dir / name
    if path.exists() and not force:
        print(f"[dryrun] cached {name}")
        return json.loads(path.read_text())
    print(f"[dryrun] lowering {arch} x {shape_name} x {mesh_tag} ...",
          flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    path.write_text(json.dumps(rec, indent=2))
    status = rec.get("status")
    extra = "" if status != "ok" else (
        f" flops={rec['flops']:.3e} dom={rec['roofline']['dominant']}"
        f" compile={rec['compile_s']}s")
    print(f"[dryrun] {name}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        failures = 0
        for arch in ARCHS:
            for shape_name in SHAPES:
                rec = run_cell(arch, shape_name, args.multi_pod,
                               force=args.force)
                failures += rec.get("status") == "error"
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, args.multi_pod, force=args.force)
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                     indent=2))
    sys.exit(0 if rec.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
