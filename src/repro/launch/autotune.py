"""Offline kernel-geometry sweep: ``python -m repro.launch.autotune``.

Runs the tuning sweep of ``repro.kernels.tuning`` over a list of launch
shapes, persists the winners to the JSON cache (default
``experiments/tuning/kernel_specs.json``; ``--cache`` / ``REPRO_TUNING_CACHE``
override), then re-reads the cache through the same lookup path the ``tuned``
engine uses and asserts every swept shape resolves — so a green run IS the
round-trip proof the CI smoke job relies on.

On a TPU host this produces real winners; on CPU the kernels run under the
Pallas interpreter, so the sweep is an end-to-end exercise of every
candidate geometry rather than a meaningful timing — use ``--repeats 1``
and tiny shapes there (the CI smoke does).

Examples::

    # production embedding-table shapes, full grid
    python -m repro.launch.autotune --sizes 16384x64x1024 65536x64x1024

    # CI smoke: tiny shape, pruned grid, interpret mode, throwaway cache
    python -m repro.launch.autotune --sizes 64x4x4 --repeats 1 \
        --block-ns 64,128 --block-ks 64 --cache /tmp/tuning.json

    # also sweep the batched megakernel's group-size axis: each size doubles
    # as a subset shape SxDxK, solved as a --stack-m reducer stack
    python -m repro.launch.autotune --sizes 256x64x128 \
        --group-ts 1,2,4,8 --stack-m 64

    # also sweep the k-means|| init-round sweep kernel: each size re-read
    # as NxDxC (C = candidate-tile capacity), winners cached under |init
    python -m repro.launch.autotune --sizes 4096x64x128 --init-sweep
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.kernels import specs, tuning


def _parse_size(s: str) -> tuple[int, int, int]:
    try:
        n, d, k = (int(v) for v in s.lower().split("x"))
        return n, d, k
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"{s!r}: expected NxDxK, e.g. 4096x64x256")


def _parse_ints(s: str) -> tuple[int, ...]:
    return tuple(int(v) for v in s.split(","))


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Sweep Lloyd-kernel block geometry and cache the winners")
    ap.add_argument("--sizes", nargs="+", type=_parse_size, required=True,
                    metavar="NxDxK", help="launch shapes to tune")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="points dtype the winners are keyed under")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per candidate (median wins)")
    ap.add_argument("--block-ns", type=_parse_ints,
                    default=tuning.BLOCK_NS, metavar="N1,N2,...",
                    help="block_n sweep grid")
    ap.add_argument("--block-ks", type=_parse_ints,
                    default=tuning.BLOCK_KS, metavar="K1,K2,...",
                    help="block_k sweep grid")
    ap.add_argument("--acc-dtypes", type=lambda s: tuple(s.split(",")),
                    default=("float32",), metavar="DT1,DT2",
                    help="on-chip acc dtypes to sweep (float32[,bfloat16])")
    ap.add_argument("--group-ts", type=_parse_ints, default=None,
                    metavar="T1,T2,...",
                    help="ALSO sweep the batched megakernel's group-size "
                         "axis over these subsets-per-grid-step values: "
                         "each NxDxK is re-read as a subset shape SxDxK and "
                         "solved as a --stack-m sized stack (winner cached "
                         "with group_t set under the |m<bucket> key)")
    ap.add_argument("--stack-m", type=int, default=8, metavar="M",
                    help="reducer-stack size for the --group-ts sweep")
    ap.add_argument("--reseed-empty", action="store_true",
                    help="time the --group-ts sweep through the in-kernel "
                         "empty-cluster reseed path (the paper-pipeline "
                         "configuration; winners land under the same key — "
                         "group size is a geometry knob either way)")
    ap.add_argument("--prune", default="none", choices=["none", "bounds"],
                    help="time the --group-ts sweep through the bound-gated "
                         "block-skipping solve path ('bounds'); results are "
                         "bitwise identical to 'none', so winners land under "
                         "the same key — but the bound state joins each "
                         "candidate's VMEM working set")
    ap.add_argument("--init-sweep", action="store_true",
                    help="ALSO sweep the k-means|| init-round sweep kernel: "
                         "each NxDxK is re-read as NxDxC (C = the "
                         "power-of-two candidate-tile capacity the round "
                         "loop pads to) and the winner lands under the "
                         "|init cache key the seeding driver consults")
    ap.add_argument("--cache", default=None,
                    help="cache path (default: REPRO_TUNING_CACHE or "
                         "experiments/tuning/kernel_specs.json)")
    ap.add_argument("--device-kind", default=None,
                    help="profile/key under this device kind instead of the "
                         "local jax device (e.g. 'TPU v4')")
    ap.add_argument("--interpret", action="store_true",
                    help="force the Pallas interpreter (default: auto — "
                         "compiled on TPU, interpreted elsewhere)")
    args = ap.parse_args(argv)

    profile = specs.get_profile(args.device_kind)
    dtype = jnp.dtype(args.dtype)
    cache = tuning.TuningCache.load(args.cache)
    print(f"device profile: {profile.device_kind} "
          f"(vmem={profile.vmem_bytes >> 20} MiB, "
          f"budget={profile.budget_bytes >> 20} MiB)  cache: {cache.path}")

    for n, d, k in args.sizes:
        best, rows = tuning.autotune_step(
            n, d, k, dtype=dtype, profile=profile, cache=cache,
            repeats=args.repeats, interpret=True if args.interpret else None,
            block_ns=args.block_ns, block_ks=args.block_ks,
            acc_dtypes=args.acc_dtypes)
        default_row = next(
            (r for r in rows
             if r["spec"].tile_shapes(n, d, k)
             == specs.DEFAULT_SPEC.tile_shapes(n, d, k)
             and r["spec"].acc_dtype == specs.DEFAULT_SPEC.acc_dtype), None)
        speedup = (default_row["time_us"] / rows[0]["time_us"]
                   if default_row else float("nan"))
        print(f"n{n} d{d} k{k}: {len(rows)} candidates -> "
              f"block_n={best.block_n} block_k={best.block_k} "
              f"acc={best.acc_dtype} "
              f"({rows[0]['time_us']:.0f} us, {speedup:.2f}x vs default)")

    # the batched megakernel's group-size axis: every size doubles as an
    # SxDxK subset shape solved as an M-stack (skipped shapes where even a
    # T=1 group busts the budget report as such and stay out of the cache)
    batched_swept = []
    if args.group_ts:
        for s, d, k in args.sizes:
            best, rows = tuning.autotune_batched(
                args.stack_m, s, d, k, dtype=dtype, profile=profile,
                cache=cache, repeats=args.repeats,
                interpret=True if args.interpret else None,
                group_ts=args.group_ts, reseed_empty=args.reseed_empty,
                prune=args.prune)
            if best is None:
                print(f"m{args.stack_m} s{s} d{d} k{k}: no feasible group "
                      f"(budget {profile.budget_bytes >> 20} MiB) — skipped")
                continue
            batched_swept.append((s, d, k))
            print(f"m{args.stack_m} s{s} d{d} k{k}: {len(rows)} group sizes "
                  f"-> group_t={best.group_t} "
                  f"({rows[0]['launches']} launches/stack, "
                  f"{rows[0]['time_us']:.0f} us)")

    # the k-means|| init-round sweep kernel: every size doubles as an NxDxC
    # shape (C re-read as the candidate-tile capacity the round loop pads
    # to); winners land under the |init-extended key the seeding driver's
    # lookup_init_spec consults
    if args.init_sweep:
        for n, d, c in args.sizes:
            best, rows = tuning.autotune_init_sweep(
                n, d, c, dtype=dtype, profile=profile, cache=cache,
                repeats=args.repeats,
                interpret=True if args.interpret else None,
                block_ns=args.block_ns, block_ks=args.block_ks,
                acc_dtypes=args.acc_dtypes)
            print(f"init n{n} d{d} c{c}: {len(rows)} candidates -> "
                  f"block_n={best.block_n} block_k={best.block_k} "
                  f"acc={best.acc_dtype} ({rows[0]['time_us']:.0f} us)")

    path = cache.save()
    print(f"wrote {len(cache.entries)} entries to {path}")

    # round-trip proof: the winners must resolve through the tuned engine's
    # own lookup path from a fresh load of the file just written
    fresh = tuning.TuningCache.load(path)
    for n, d, k in args.sizes:
        key = tuning.cache_key(profile.device_kind, dtype, n, d, k)
        spec = fresh.get(key)
        assert spec is not None, f"cache round-trip failed for {key}"
    for s, d, k in batched_swept:
        key = tuning.cache_key(profile.device_kind, dtype, s, d, k,
                               m=args.stack_m)
        spec = fresh.get(key)
        assert spec is not None and spec.group_t, \
            f"batched cache round-trip failed for {key}"
    if args.init_sweep:
        for n, d, c in args.sizes:
            key = tuning.cache_key(profile.device_kind, dtype, n, d, c,
                                   kernel="init")
            spec = fresh.get(key)
            assert spec is not None, \
                f"init cache round-trip failed for {key}"
    print(f"cache round-trip OK ({len(args.sizes)} shapes"
          + (f" + {len(batched_swept)} stacks" if batched_swept else "")
          + (f" + {len(args.sizes)} init sweeps" if args.init_sweep else "")
          + " resolve)")


if __name__ == "__main__":
    main()
