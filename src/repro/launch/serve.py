"""Serving: prefill + batched decode step builders and a greedy generator.

``make_prefill_step`` / ``make_decode_step`` are what the dry-run lowers for
the prefill_32k / decode_32k / long_500k cells.  The CLI serves a smoke
model with batched random requests as the runnable example.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import encdec, registry, transformer


def make_prefill_step(cfg):
    """tokens (B,S) [+ embeds] -> last-position logits (B, V)."""
    if cfg.is_encdec:
        def prefill(params, batch):
            memory = encdec.encode(params, batch["embeds"], cfg)
            logits = encdec.decode_train(params, batch["tokens"], memory, cfg)
            return logits[:, -1]
        return prefill

    def prefill(params, batch):
        logits, _, _ = transformer.forward(params, batch["tokens"], cfg)
        return logits[:, -1]
    return prefill


def make_decode_step(cfg):
    """(params, caches, tokens (B,1), pos) -> (logits (B,1,V), caches)."""
    return registry.decode_step_fn(cfg)


def greedy_generate(cfg, params, prompt_tokens, *, max_new: int = 32,
                    enc_embeds=None):
    """Incremental greedy decoding (example / integration-test path)."""
    b, s0 = prompt_tokens.shape
    max_len = s0 + max_new
    enc_len = enc_embeds.shape[1] if enc_embeds is not None else 0
    caches = registry.init_decode_caches(cfg, b, max_len, enc_len)
    if cfg.is_encdec:
        memory = encdec.encode(params, enc_embeds, cfg)
        caches = encdec.prefill_memory(params, memory, caches, cfg)
    raw_step = make_decode_step(cfg)
    step = jax.jit(raw_step)

    # Prompt pass: ONE jitted scan of the decode step builds the prompt's
    # KV caches (the cache write path is single-token, so the scan replays
    # it per position — but inside one compiled program, not s0 dispatches,
    # and the per-step lm_head logits are dead code XLA eliminates)...
    @jax.jit
    def warm(params, caches, toks):
        def body(c, xs):
            tok, pos = xs
            _, c = raw_step(params, c, tok, pos)
            return c, ()
        c, _ = jax.lax.scan(
            body, caches,
            (jnp.swapaxes(toks, 0, 1)[:, :, None],
             jnp.arange(toks.shape[1], dtype=jnp.int32)))
        return c

    caches = warm(params, caches, prompt_tokens)
    # ...and the prefill step scores the whole prompt in one full-sequence
    # forward, yielding the first new token's logits without s0 decode hops.
    prefill = jax.jit(make_prefill_step(cfg))
    batch = {"tokens": prompt_tokens}
    if cfg.is_encdec:
        batch["embeds"] = enc_embeds
    logits = prefill(params, batch)                      # (B, V)
    out = [prompt_tokens]
    for t in range(s0, max_len):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(nxt)
        step_logits, caches = step(params, caches, nxt, jnp.int32(t))
        logits = step_logits[:, -1]
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = registry.init_params(jax.random.key(0), cfg)
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(jax.random.key(2),
                                (args.batch, 32, cfg.d_model), jnp.float32)
    t0 = time.time()
    out = greedy_generate(cfg, params, prompts, max_new=args.max_new,
                          enc_embeds=enc)
    dt = time.time() - t0
    print(f"served batch={args.batch} new_tokens={args.max_new} "
          f"in {dt:.1f}s ({args.batch * args.max_new / dt:.1f} tok/s)")
    print("sample:", out[0, -args.max_new:])


if __name__ == "__main__":
    main()
