import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb driver: lower optimization variants of the three chosen
cells and print baseline-vs-variant roofline deltas.

Variants are tagged; JSONs land next to the baselines in experiments/dryrun/
as <arch>__<shape>__<mesh>__<tag>.json so EXPERIMENTS.md §Perf can cite
exact numbers.  Baselines are never overwritten (paper-faithful vs optimized
are separate records).

  python -m repro.launch.perf_variants --cell A1   # dsv3 train, a2a MoE
  python -m repro.launch.perf_variants --cell B1   # cr+ decode, TP-only
  ...
"""
import argparse
import dataclasses
import json
import math
import time

from repro.configs import ARCHS
from repro.launch.dryrun import OUT_DIR, lower_cell

VARIANTS = {
    # --- Cell A: deepseek-v3-671b x train_4k (worst roofline fraction) ---
    "A1": dict(arch="deepseek-v3-671b", shape="train_4k",
               overrides={"moe": dataclasses.replace(
                   ARCHS["deepseek-v3-671b"].moe, dispatch="a2a")},
               note="expert-parallel all_to_all MoE (local expert grads)"),
    "A2": dict(arch="deepseek-v3-671b", shape="train_4k",
               overrides={"moe": dataclasses.replace(
                   ARCHS["deepseek-v3-671b"].moe, dispatch="a2a"),
                   "remat": "dots"},
               note="a2a MoE + checkpoint_dots remat policy"),
    "A3": dict(arch="deepseek-v3-671b", shape="train_4k",
               overrides={"moe": dataclasses.replace(
                   ARCHS["deepseek-v3-671b"].moe, dispatch="a2a",
                   capacity_factor=1.0)},
               note="a2a MoE + capacity_factor 1.0 (drop-heavier)"),
    "A4": dict(arch="deepseek-v3-671b", shape="train_4k",
               overrides={"moe": dataclasses.replace(
                   ARCHS["deepseek-v3-671b"].moe, dispatch="a2a"),
                   "remat": "save_moe"},
               note="a2a MoE + save-moe-out remat (backward skips the "
                    "recompute all_to_alls)"),
    # --- Cell B: command-r-plus-104b x decode_32k (most collective-bound) -
    "B1": dict(arch="command-r-plus-104b", shape="decode_32k", fsdp=False,
               note="TP-only param layout for decode (no FSDP all-gather)"),
    "B2": dict(arch="command-r-plus-104b", shape="decode_32k", fsdp=False,
               note="B1 + 2D vocab-tensor layout + replicated decode q "
                    "(flash-decoding reduction over seq-sharded cache)"),
    "B3": dict(arch="command-r-plus-104b", shape="decode_32k", fsdp=False,
               note="B1 + 2D vocab tensors + pinned ff-activation sharding "
                    "(stops per-layer weight re-transposition in the scan)"),
    "B4": dict(arch="command-r-plus-104b", shape="decode_32k",
               layout="row_parallel",
               note="row-parallel decode layout: weights sharded on the "
                    "contracting dim (zero weight movement, MB-scale psums)"),
    "B5": dict(arch="command-r-plus-104b", shape="decode_32k", fsdp=False,
               note="column/row Megatron decode: every activation pinned "
                    "(x, q, o, ff) — solver has no resharding freedom"),
    # --- Cell B~: mixtral-8x7b x prefill_32k (most collective-bound) ---
    "M1": dict(arch="mixtral-8x7b", shape="prefill_32k",
               overrides={"moe": dataclasses.replace(
                   ARCHS["mixtral-8x7b"].moe, dispatch="local")},
               note="shard_map-local gather dispatch + TP expert FFN "
                    "(kills the dataset-sized combine all-reduce)"),
    "M2": dict(arch="mixtral-8x7b", shape="train_4k",
               overrides={"moe": dataclasses.replace(
                   ARCHS["mixtral-8x7b"].moe, dispatch="local"),
                   "remat": "save_moe"},
               note="local dispatch + save-moe remat, train shape"),
    # --- Cell C is driven by kmeans_dryrun.py (paper's own technique);
    #     its kernel-backend variants live in KMEANS_VARIANTS below ---
}

# Cell C: the paper's own technique.  Variants swap the Lloyd kernel path
# every S2 reducer executes (see src/repro/kernels/__init__.py for the
# backend taxonomy); kmeans_dryrun lowers the full production problem with
# the chosen backend and we diff its roofline against the jnp baseline.
KMEANS_VARIANTS = {
    "C1": dict(backend="pallas",
               note="two-kernel Pallas Lloyd (assign + update: points "
                    "stream HBM twice per iteration)"),
    "C2": dict(backend="fused",
               note="fused single-pass Lloyd kernel (one HBM sweep per "
                    "iteration; labels/distances never leave VMEM)"),
    "C3": dict(backend="resident",
               note="VMEM-resident multi-iteration Lloyd: whole solve in "
                    "one kernel launch where the subset fits VMEM — points "
                    "stream HBM once per SOLVE, i.e. iters x fewer sweeps "
                    "than the fused per-step kernel"),
    "C4": dict(backend="batched",
               note="batched-resident stack megakernel: each device's whole "
                    "S2 reducer stack is ONE pipelined launch (grid over "
                    "groups of T subsets, group-batched MXU matmuls, next "
                    "group's points DMA'd while the current group iterates) "
                    "— launches drop M -> ceil(M/T) vs the vmap'd C3"),
    "C5": dict(backend="batched", reseed_empty=True,
               baseline=dict(backend="fused", reseed_empty=True),
               note="reseed-on batched megakernel vs the OLD vmap fallback "
                    "(host-side fused loop with per-iteration host reseed, "
                    "what reseed_empty used to force): the in-kernel "
                    "farthest-point reseed keeps the one-launch-per-stack "
                    "property on the paper-pipeline quality configuration"),
    "C6": dict(backend="batched", prune="bounds",
               baseline=dict(backend="batched"),
               note="bound-pruned batched megakernel vs the exact batched "
                    "baseline: each group carries per-block margins + "
                    "accumulated centroid drift and skips a block's score "
                    "matmul when the triangle-inequality bound proves no "
                    "assignment can change — bit-for-bit-identical results, "
                    "late iterations trade MXU dots for a branch test "
                    "(kernel_bench's pruned row measures the skip fraction)"),
}


def _kmeans_variant_suffix(backend: str, reseed_empty: bool,
                           prune: str = "none") -> str:
    """Record-name suffix kmeans_dryrun writes for a (backend, reseed,
    prune) triple — mirrors its ``file_tag`` rule exactly: the jnp baseline
    carries no backend suffix, reseed appends ``__reseed`` and pruning
    ``__prune`` either way."""
    suffix = "" if backend == "jnp" else f"__{backend}"
    suffix += "__reseed" if reseed_empty else ""
    return suffix + ("__prune" if prune != "none" else "")


def run_kmeans(tag: str, force: bool = False):
    """Lower the kmeans dry-run with a non-default kernel backend and diff
    its roofline terms against the baseline records (the jnp lowering, or a
    variant-specific baseline — C5 diffs reseed-on batched against the old
    host-loop fallback path)."""
    from repro.launch import kmeans_dryrun

    v = KMEANS_VARIANTS[tag]
    backend = v["backend"]
    reseed = bool(v.get("reseed_empty"))
    prune = v.get("prune", "none")
    mesh_tag = "16x16"
    stages = ("kmeans-pkmeans-iter", "kmeans-ipkmeans-s2s3")
    suffix = _kmeans_variant_suffix(backend, reseed, prune)

    if force or not all(
            (OUT_DIR / f"{s}__{mesh_tag}{suffix}.json").exists()
            for s in stages):
        kmeans_dryrun.lower_all(multi_pod=False, backend=backend,
                                reseed_empty=reseed, prune=prune)
    base_cfg = v.get("baseline", dict(backend="jnp"))
    base_suffix = _kmeans_variant_suffix(base_cfg["backend"],
                                         bool(base_cfg.get("reseed_empty")),
                                         base_cfg.get("prune", "none"))
    # the jnp baseline is the slowest compile of the sweep — only --force a
    # re-lower for variant-specific baselines
    refresh = force and base_cfg["backend"] != "jnp"
    if refresh or not all(
            (OUT_DIR / f"{s}__{mesh_tag}{base_suffix}.json").exists()
            for s in stages):
        kmeans_dryrun.lower_all(
            multi_pod=False, backend=base_cfg["backend"],
            reseed_empty=bool(base_cfg.get("reseed_empty")),
            prune=base_cfg.get("prune", "none"))

    print(f"[{tag}] {v['note']}")
    out = []
    for stage in stages:
        base = json.loads(
            (OUT_DIR / f"{stage}__{mesh_tag}{base_suffix}.json").read_text())
        rec = json.loads(
            (OUT_DIR / f"{stage}__{mesh_tag}{suffix}.json").read_text())
        print(f"  {stage}:")
        for term in ("compute_s", "memory_s", "collective_s"):
            b, n = base["roofline"][term], rec["roofline"][term]
            print(f"    {term:13s}: {b:.3e} -> {n:.3e}"
                  + (f"  ({b / n:.2f}x)" if n > 0 else ""))
        out.append(rec)

    if backend == "resident":
        # iterations-per-launch: the analytic per-solve HBM model for one S2
        # reducer's subset — fused pays one points sweep per iteration,
        # resident pays one per solve (benchmarks/kernel_bench.py's model)
        from benchmarks.kernel_bench import lloyd_solve_hbm_bytes
        from repro.kernels.resident import (max_resident_points,
                                            resident_feasible)
        n_sub = -(-kmeans_dryrun.N // kmeans_dryrun.M)
        iters = kmeans_dryrun.MAX_ITERS
        d, k = kmeans_dryrun.D, kmeans_dryrun.K
        fus = lloyd_solve_hbm_bytes(n_sub, d, k, iters, "fused")
        res = lloyd_solve_hbm_bytes(n_sub, d, k, iters, "resident")
        print(f"  per-solve HBM model (subset n={n_sub}, d={d}, k={k}, "
              f"iters={iters}):")
        print(f"    fused   : {fus:.3e} B  ({iters} point sweeps/launch x 1)")
        print(f"    resident: {res:.3e} B  (1 point sweep/solve, "
              f"{fus / res:.1f}x less; vmem_feasible="
              f"{resident_feasible(n_sub, d, k)})")
        if not resident_feasible(n_sub, d, k):
            n_max = max_resident_points(d, k)
            m_needed = -(-kmeans_dryrun.N // max(n_max, 1))
            print(f"    -> subset too big for VMEM (falls back to fused); "
                  f"resident fits n<={n_max} at this (d, k), i.e. "
                  f"M>={m_needed} reducers — the paper's more-reducers knob "
                  f"IS the feasibility knob")

    if backend == "batched":
        # launches-per-stack: each device's reducer stack collapses from
        # m_loc single-block grid steps (vmap'd resident) to ceil(m_loc/T)
        # pipelined groups (benchmarks/kernel_bench.py's stack model)
        from repro.kernels.batch_resident import (batched_group_size,
                                                  batched_group_vmem_bytes)
        n_sub = -(-kmeans_dryrun.N // kmeans_dryrun.M)
        d, k = kmeans_dryrun.D, kmeans_dryrun.K
        n_dev = math.prod(int(v) for v in mesh_tag.split("x"))
        m_loc = kmeans_dryrun.M // n_dev             # subsets per device
        t = batched_group_size(m_loc, n_sub, d, k, prune=prune)
        mode = ("reseed-on " if reseed else "") + (
            "bound-pruned " if prune != "none" else "")
        print(f"  per-stack launch model ({mode}m_loc={m_loc} "
              f"reducers/device, subset n={n_sub}, d={d}, k={k}):")
        if t:
            grp = batched_group_vmem_bytes(t, n_sub, d, k, prune=prune)
            print(f"    group_t={t} ({grp:.3e} B/group)"
                  f": {m_loc} launches -> {-(-m_loc // t)}"
                  + (" (the reseed runs inside the group loop — no host "
                     "fallback, no extra launches)" if reseed else ""))
            if prune != "none":
                delta = grp - batched_group_vmem_bytes(t, n_sub, d, k)
                print(f"    bound state: +{delta:.3e} B/group VMEM "
                      f"(cached labels + margins + drift + skip counters) "
                      f"buys skipped score matmuls in late iterations")
        else:
            print(f"    -> one subset alone busts the VMEM budget; stack "
                  f"falls back to the vmap-of-solve path (size subsets via "
                  f"more reducers until batched_group_size >= 1)")
    return out


def run(tag: str, force: bool = False):
    if tag in KMEANS_VARIANTS:
        return run_kmeans(tag, force)
    v = VARIANTS[tag]
    mesh_tag = "16x16"
    name = f"{v['arch']}__{v['shape']}__{mesh_tag}__{tag}.json"
    path = OUT_DIR / name
    if path.exists() and not force:
        rec = json.loads(path.read_text())
    else:
        t0 = time.time()
        rec = lower_cell(v["arch"], v["shape"], multi_pod=False,
                         overrides=v.get("overrides"), fsdp=v.get("fsdp"),
                         layout=v.get("layout", "train"))
        rec["variant"] = tag
        rec["note"] = v["note"]
        path.write_text(json.dumps(rec, indent=2))
    base = json.loads(
        (OUT_DIR / f"{v['arch']}__{v['shape']}__{mesh_tag}.json").read_text())

    def fmt(r):
        rf = r["roofline"]
        return (f"comp={rf['compute_s']:.3e} mem={rf['memory_s']:.3e} "
                f"coll={rf['collective_s']:.3e} dom={rf['dominant']}")

    print(f"[{tag}] {v['note']}")
    print(f"  baseline: {fmt(base)}")
    if rec.get("status") != "ok":
        print(f"  variant : FAILED {rec.get('error', '')[:300]}")
        return rec
    print(f"  variant : {fmt(rec)}")
    for term in ("compute_s", "memory_s", "collective_s"):
        b, n = base["roofline"][term], rec["roofline"][term]
        if b > 0:
            print(f"  {term:13s}: {b:.3e} -> {n:.3e}  ({b / max(n, 1e-12):.2f}x)")
    bb = max(base["roofline"][t] for t in
             ("compute_s", "memory_s", "collective_s"))
    nn = max(rec["roofline"][t] for t in
             ("compute_s", "memory_s", "collective_s"))
    print(f"  bound: {bb:.3e} -> {nn:.3e}  ({bb / max(nn, 1e-12):.2f}x); "
          f"roofline fraction {base['roofline']['compute_s'] / bb:.3f} -> "
          f"{rec['roofline']['compute_s'] / nn:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=list(VARIANTS) + list(KMEANS_VARIANTS) + ["all"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    tags = (list(VARIANTS) + list(KMEANS_VARIANTS)
            if args.cell == "all" else [args.cell])
    for t in tags:
        run(t, force=args.force)


if __name__ == "__main__":
    main()
