from repro.launch import mesh, specs

__all__ = ["mesh", "specs"]
