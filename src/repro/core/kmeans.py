"""Single-shard Lloyd's k-means, the unit of work each IPKMeans "reducer" runs.

The whole solver is a single ``lax.while_loop`` — no host round-trips, no
collectives — so under ``shard_map`` every device iterates *independently* to
convergence, which is exactly the paper's "each reducer runs one complete
k-means" semantics (Algorithm 4).

Three interchangeable backends drive the Lloyd iteration:

  * ``'jnp'``   — pure-jnp reference (default; also the test oracle),
  * ``'pallas'``— two Pallas kernels (assign, then centroid update): the
    points stream from HBM twice per iteration,
  * ``'fused'`` — single-pass Pallas kernel (``kernels/fused.py``): assign
    and accumulate in one grid sweep, labels/distances never leave VMEM —
    the paper's one-job argument applied to the memory hierarchy.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import metrics


BACKENDS = ("jnp", "pallas", "fused")


class KMeansParams(NamedTuple):
    max_iters: int = 300
    tol: float = 1e-6             # paper: "until centroids stop moving"
    backend: str = "jnp"          # 'jnp' | 'pallas' | 'fused'


class KMeansResult(NamedTuple):
    centroids: jnp.ndarray        # (k, d)
    sse: jnp.ndarray              # () total SSE on this shard
    asse: jnp.ndarray             # () average SSE (paper's merge criterion)
    iters: jnp.ndarray            # () int32 Lloyd iterations executed
    converged: jnp.ndarray        # () bool


def _assign(points, centroids, backend: str):
    """Nearest-centroid labels + squared distances, (n,) i32 and (n,) f32."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend: {backend!r} "
                         f"(expected one of {BACKENDS})")
    if backend in ("pallas", "fused"):
        from repro.kernels import ops
        return ops.assign(points, centroids)
    d2 = metrics.pairwise_sq_dists(points, centroids)
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    mind = jnp.take_along_axis(d2, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return labels, mind


def _update(points, labels, mind, mask, k: int, old_centroids, backend: str):
    """Weighted centroid recomputation; empty clusters keep their centroid."""
    w = jnp.ones(points.shape[0], points.dtype) if mask is None \
        else mask.astype(points.dtype)
    if backend == "pallas":
        from repro.kernels import ops
        sums, counts = ops.centroid_update(points, labels, w, k)
    else:
        onehot = jax.nn.one_hot(labels, k, dtype=points.dtype) * w[:, None]
        sums = onehot.T @ points                                    # (k, d)
        counts = jnp.sum(onehot, axis=0)                            # (k,)
    new_c = jnp.where(counts[:, None] > 0.0,
                      sums / jnp.maximum(counts[:, None], 1.0),
                      old_centroids)
    # weight-scaled, matching the fused kernel (identical for 0/1 masks)
    shard_sse = jnp.sum(w * mind)
    return new_c, shard_sse


def lloyd_step(points, centroids, mask=None, backend: str = "jnp"):
    """One Lloyd iteration: assign + update. Returns (new_centroids, sse)."""
    k = centroids.shape[0]
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend: {backend!r} "
                         f"(expected one of {BACKENDS})")
    if backend == "fused":
        from repro.kernels import ops
        w = None if mask is None else mask.astype(points.dtype)
        sums, counts, shard_sse = ops.lloyd_step_fused(points, centroids, w)
        new_c = jnp.where(counts[:, None] > 0.0,
                          sums / jnp.maximum(counts[:, None], 1.0),
                          centroids.astype(jnp.float32))
        # f32 accumulators; cast back so while_loop carries keep their dtype
        return new_c.astype(centroids.dtype), shard_sse
    labels, mind = _assign(points, centroids, backend)
    return _update(points, labels, mind, mask, k, centroids, backend)


@partial(jax.jit, static_argnames=("params",))
def kmeans(points: jnp.ndarray,
           init_centroids: jnp.ndarray,
           mask: jnp.ndarray | None = None,
           params: KMeansParams = KMeansParams()) -> KMeansResult:
    """Run Lloyd's algorithm to convergence on one shard of data.

    Args:
      points: (n, d) float array.  Padded rows allowed when ``mask`` given.
      init_centroids: (k, d) initial centroids (the paper uses the *same*
        initial centroids for every reducer, so callers broadcast these).
      mask: optional (n,) bool — False rows are padding and fully ignored.
      params: loop controls + assignment backend.
    """
    k = init_centroids.shape[0]

    def cond(carry):
        c, prev_c, it, shift = carry
        return jnp.logical_and(it < params.max_iters, shift > params.tol)

    def body(carry):
        c, _, it, _ = carry
        new_c, _ = lloyd_step(points, c, mask, params.backend)
        return (new_c, c, it + 1, metrics.centroid_shift(new_c, c))

    init = (init_centroids, init_centroids, jnp.int32(0), jnp.asarray(jnp.inf))
    final_c, _, iters, shift = jax.lax.while_loop(cond, body, init)

    # final statistics with the converged centroids
    labels, mind = _assign(points, final_c, params.backend)
    w = jnp.ones(points.shape[0], points.dtype) if mask is None \
        else mask.astype(points.dtype)
    total_sse = jnp.sum(w * mind)
    cnt = jnp.sum(w)
    # empty shards must never win the min-ASSE merge: ASSE = +inf
    asse = jnp.where(cnt > 0.0, total_sse / jnp.maximum(cnt, 1.0), jnp.inf)
    return KMeansResult(centroids=final_c,
                        sse=total_sse,
                        asse=asse,
                        iters=iters,
                        converged=shift <= params.tol)


def kmeans_batched(subsets: jnp.ndarray,
                   masks: jnp.ndarray,
                   init_centroids: jnp.ndarray,
                   params: KMeansParams = KMeansParams()) -> KMeansResult:
    """vmap of :func:`kmeans` over a stack of subsets — (M, S, d) + (M, S).

    This is the per-device body of IPKMeans stage 2: when more subsets than
    devices exist, each device runs a stack of complete k-means instances
    (Hadoop would queue reducers the same way).
    """
    fn = lambda p, m: kmeans(p, init_centroids, m, params)
    return jax.vmap(fn)(subsets, masks)
