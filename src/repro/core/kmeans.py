"""Single-shard Lloyd's k-means, the unit of work each IPKMeans "reducer" runs.

The solver delegates the WHOLE solve to a :class:`repro.kernels.engine
.LloydEngine` looked up from ``params.backend`` — engines that only implement
``step`` get the generic host-side ``lax.while_loop`` (no host round-trips,
no collectives, so under ``shard_map`` every device iterates *independently*
to convergence, exactly the paper's "each reducer runs one complete k-means"
semantics, Algorithm 4); engines that own their convergence loop
(``resident``) run it entirely on-chip, one kernel launch per solve.

Registered engines (see ``src/repro/kernels/__init__.py`` for the taxonomy):
``jnp`` (reference/oracle) | ``pallas`` (two-kernel, labels as product) |
``fused`` (one HBM sweep per iteration) | ``resident`` (one HBM sweep per
*solve* — VMEM-resident loop with automatic fused fallback) | ``batched``
(resident semantics whose reducer STACKS lower to one pipelined multi-group
launch) | ``tuned`` (resident behaviour + autotuned kernel geometry from
the tuning cache).

``reseed_empty`` re-seeds zero-count centroids at the farthest in-subset
point (k-means++-style, Bahmani et al.): with small subsets a centroid frozen
at a bad init is a degenerate seed that keep-old-centroid semantics never
repairs — this flag repairs it in every engine.  For the whole-solve engines
(``resident``/``batched``/``tuned``) the reseed runs *inside* their kernels'
convergence loops, so the paper's quality configuration keeps the
one-launch-per-solve / one-launch-per-stack property (host-side reseeding
remains only on the host-loop engines and infeasible-shape fallbacks).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.kernels import engine as engines
from repro.kernels import ref


def __getattr__(name):
    # BACKENDS is the historical public constant; computed per-access (not
    # snapshotted at import) so late-registered engines — 'tuned' lands when
    # kernels.tuning imports, custom engines whenever callers register —
    # are never invisible here.
    if name == "BACKENDS":
        return engines.available()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class KMeansParams(NamedTuple):
    max_iters: int = 300
    tol: float = 1e-6             # paper: "until centroids stop moving"
    backend: str = "jnp"          # any name in engines.available(): 'jnp'|
                                  # 'pallas'|'fused'|'resident'|'batched'|'tuned'
    reseed_empty: bool = False    # re-seed empty clusters at farthest points
    prune: str = "none"           # 'none' | 'bounds': bound-gated block
                                  # skipping in the whole-solve kernels
                                  # (bit-for-bit-identical results)
    init: str = "given"           # 'given' | 'sample' | 'kmeans++' |
                                  # 'kmeans||': centroid seeding, resolved
                                  # on host at the pipeline entry points
                                  # (kmeans/ipkmeans take a key for it)


class KMeansResult(NamedTuple):
    centroids: jnp.ndarray        # (k, d)
    sse: jnp.ndarray              # () total SSE on this shard
    asse: jnp.ndarray             # () average SSE (paper's merge criterion)
    iters: jnp.ndarray            # () int32 Lloyd iterations executed
    converged: jnp.ndarray        # () bool


def lloyd_step(points, centroids, mask=None, backend: str = "jnp"):
    """One Lloyd iteration: assign + update. Returns (new_centroids, sse)."""
    engine = engines.get_engine(backend)
    w = None if mask is None else mask.astype(points.dtype)
    sums, counts, shard_sse = engine.step(points, centroids, w)
    new_c = ref.divide_or_keep(sums, counts, centroids.astype(jnp.float32))
    # f32 accumulators; cast back so while_loop carries keep their dtype
    return new_c.astype(centroids.dtype), shard_sse


@partial(jax.jit, static_argnames=("params",))
def update_minibatch(points, centroids, counts, mask=None,
                     params: KMeansParams = KMeansParams()):
    """One Sculley-style mini-batch refresh of a served centroid set.

    (n,d),(k,d),(k,)[,(n,)] -> (centroids (k,d), counts (k,) f32, sse () f32).

    The sampling-based counterpart of :func:`kmeans`: instead of re-running
    the full solve, fold one arriving batch into the running centroids with
    per-center count-decayed learning rates (``eta = 1/count``; the
    ``ref.minibatch_merge`` closed form).  ``counts`` carries the per-center
    mass across calls — seed it from the full solve's cluster sizes (or
    zeros to let the first batches dominate) and thread the returned counts
    into the next call.  ``sse`` scores the batch against the *incoming*
    centroids, so a rising series signals drift worth a full re-solve (see
    docs/serving.md).  Dispatches on ``params.backend`` like every solver
    entry point: the kernel engines fold the whole refresh into one fused
    HBM sweep; only ``max_iters``/``tol``-style loop controls are unused
    (a refresh is one pass by construction).
    """
    engine = engines.get_engine(params.backend)
    w = None if mask is None else mask.astype(points.dtype)
    return engine.update_minibatch(points, centroids, counts, w)


def _init_backend(backend: str) -> str:
    """Which k-means|| sweep implementation a Lloyd backend implies: the
    jnp engine gets the jnp oracle sweep, every kernel engine the fused
    Pallas sweep."""
    return "ref" if backend == "jnp" else "kernel"


def kmeans(points: jnp.ndarray,
           init_centroids: jnp.ndarray | None = None,
           mask: jnp.ndarray | None = None,
           params: KMeansParams = KMeansParams(),
           *, key: jax.Array | None = None,
           k: int | None = None) -> KMeansResult:
    """Run Lloyd's algorithm to convergence on one shard of data.

    Args:
      points: (n, d) float array.  Padded rows allowed when ``mask`` given.
      init_centroids: (k, d) initial centroids (the paper uses the *same*
        initial centroids for every reducer, so callers broadcast these).
        May be ``None`` when ``params.init != "given"``.
      mask: optional (n,) bool — False rows are padding and fully ignored.
      params: loop controls + Lloyd engine selection + init strategy.
      key: PRNG key for ``params.init != "given"`` (seeding runs on host at
        this entry point — the k-means|| rounds are a host loop over fused
        kernel launches, so they cannot live inside the jitted solver core).
      k: cluster count for ``params.init != "given"`` (defaults to
        ``init_centroids.shape[0]`` when centroids were also given).
    """
    if params.init != "given":
        from repro.core import init as init_mod
        if key is None:
            raise ValueError(f"params.init={params.init!r} needs key=")
        kk = k if k is not None else (
            None if init_centroids is None else init_centroids.shape[0])
        if kk is None:
            raise ValueError(f"params.init={params.init!r} needs k= (or "
                             f"init_centroids to take the count from)")
        w = None if mask is None else mask.astype(jnp.float32)
        init_centroids = init_mod.resolve_init(
            points, key, int(kk), params.init, weights=w,
            backend=_init_backend(params.backend))
        params = params._replace(init="given")
    elif init_centroids is None:
        raise ValueError('init="given" needs init_centroids')
    return _kmeans_core(points, init_centroids, mask, params)


@partial(jax.jit, static_argnames=("params",))
def _kmeans_core(points: jnp.ndarray,
                 init_centroids: jnp.ndarray,
                 mask: jnp.ndarray | None = None,
                 params: KMeansParams = KMeansParams()) -> KMeansResult:
    engine = engines.get_engine(params.backend)
    w = None if mask is None else mask.astype(points.dtype)
    final_c, total_sse, iters, converged = engine.solve(
        points, init_centroids, w,
        max_iters=params.max_iters, tol=params.tol,
        reseed_empty=params.reseed_empty, prune=params.prune)

    cnt = metrics.masked_count(mask, points.shape[0])
    # empty shards must never win the min-ASSE merge: ASSE = +inf
    asse = jnp.where(cnt > 0.0, total_sse / jnp.maximum(cnt, 1.0), jnp.inf)
    return KMeansResult(centroids=final_c.astype(init_centroids.dtype),
                        sse=total_sse,
                        asse=asse,
                        iters=iters,
                        converged=converged)


@partial(jax.jit, static_argnames=("params",))
def kmeans_batched(subsets: jnp.ndarray,
                   masks: jnp.ndarray,
                   init_centroids: jnp.ndarray,
                   params: KMeansParams = KMeansParams()) -> KMeansResult:
    """A stack of complete k-means solves — (M, S, d) + (M, S).

    This is the per-device body of IPKMeans stage 2: when more subsets than
    devices exist, each device runs a stack of complete k-means instances
    (Hadoop would queue reducers the same way).  The stack delegates WHOLE
    to ``engine.solve_batched``: the base hook is a vmap of ``solve`` (so
    per-subset engines — including ``resident``, whose vmap is a *serialized
    grid* of single-block kernels — behave exactly as before), while
    ``backend="batched"`` lowers the stack to ONE pipelined multi-group
    megakernel launch (``kernels/batch_resident.py``): per-stack launches
    drop M -> ceil(M/T) and the next group's HBM stream overlaps the current
    group's iterations.

    Empty (all-padding) subsets keep the kmeans contract: sse 0 and
    ASSE=+inf, so they never win the min-ASSE merge.

    Seeding note: stacks always take explicit ``init_centroids`` — the
    paper feeds every reducer the SAME seeds, and this function runs inside
    jit / ``shard_map`` where host-side init resolution cannot live.
    Resolve ``init != "given"`` at the entry points (``kmeans`` /
    ``ipkmeans`` / ``ipkmeans_distributed``) and pass the result down.
    """
    if params.init != "given":          # params is static: trace-time guard
        raise ValueError(
            f"kmeans_batched requires init='given' (got {params.init!r}): "
            f"resolve seeding at the kmeans/ipkmeans entry points")
    engine = engines.get_engine(params.backend)
    w = None if masks is None else masks.astype(subsets.dtype)
    final_c, total_sse, iters, converged = engine.solve_batched(
        subsets, init_centroids, w,
        max_iters=params.max_iters, tol=params.tol,
        reseed_empty=params.reseed_empty, prune=params.prune)

    if masks is None:
        cnt = jnp.full((subsets.shape[0],), float(subsets.shape[1]),
                       jnp.float32)
    else:
        cnt = jnp.sum(masks.astype(jnp.float32), axis=1)
    # empty shards must never win the min-ASSE merge: ASSE = +inf
    asse = jnp.where(cnt > 0.0, total_sse / jnp.maximum(cnt, 1.0), jnp.inf)
    return KMeansResult(centroids=final_c.astype(init_centroids.dtype),
                        sse=total_sse,
                        asse=asse,
                        iters=iters,
                        converged=converged)
