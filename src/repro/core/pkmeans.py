"""PKMeans baseline (Zhao et al. 2009) — the paper's comparison target.

One Lloyd iteration == one MapReduce job: mappers assign points, <=K reducers
average.  The TPU adaptation keeps the per-iteration global synchronization
explicit: points are sharded over the flattened mesh axis and every iteration
performs a ``psum`` of (sums, counts, shift) — that all-reduce is the
job-per-iteration overhead the paper attacks, and it is what the I/O model and
the roofline collective term meter.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import metrics
from repro.core.kmeans import KMeansParams
from repro.kernels import engine as engines
from repro.kernels import ref


class PKMeansResult(NamedTuple):
    centroids: jnp.ndarray     # (k, d)
    sse: jnp.ndarray           # () total SSE over the full dataset
    iters: jnp.ndarray         # () int32 — one MapReduce job per iteration
    converged: jnp.ndarray     # () bool


def _local_stats(points, centroids, mask, backend):
    """Mapper + combiner: local partial (sums, counts, sse) — one
    ``engine.step`` of the selected Lloyd engine.  PKMeans is structurally
    per-iteration (the psum between steps IS the baseline's overhead), so it
    always drives engines stepwise, never ``engine.solve``."""
    w = None if mask is None else mask.astype(points.dtype)
    return engines.get_engine(backend).step(points, centroids, w)


@partial(jax.jit, static_argnames=("params",))
def pkmeans(points: jnp.ndarray,
            init_centroids: jnp.ndarray,
            mask: jnp.ndarray | None = None,
            params: KMeansParams = KMeansParams()) -> PKMeansResult:
    """Single-process PKMeans: global Lloyd to convergence.

    Numerically identical to the distributed version (the psum is exact), so
    this is both the reference and the single-machine-k-means benchmark line
    used in the paper's Fig 8 / Table 3.
    """
    def cond(carry):
        c, _, it, shift = carry
        return jnp.logical_and(it < params.max_iters, shift > params.tol)

    def body(carry):
        c, _, it, _ = carry
        sums, counts, _ = _local_stats(points, c, mask, params.backend)
        new_c = ref.divide_or_keep(sums, counts,
                                   c.astype(sums.dtype)).astype(c.dtype)
        if params.reseed_empty:
            w = None if mask is None else mask.astype(points.dtype)
            new_c = engines.reseed_empty_clusters(
                engines.get_engine(params.backend), points, w, new_c, counts)
        return (new_c, c, it + 1, metrics.centroid_shift(new_c, c))

    init = (init_centroids, init_centroids, jnp.int32(0), jnp.asarray(jnp.inf))
    final_c, _, iters, shift = jax.lax.while_loop(cond, body, init)
    total = metrics.sse(points, final_c, mask)
    return PKMeansResult(final_c, total, iters, shift <= params.tol)


def pkmeans_sharded(mesh,
                    axis_names: tuple[str, ...],
                    params: KMeansParams = KMeansParams()):
    """Build a shard_map'd PKMeans step for a mesh: points sharded over the
    flattened ``axis_names``; each Lloyd iteration all-reduces (K*d + K + 1)
    floats — the explicit per-iteration collective.

    Returns a function (points_sharded, init_centroids, mask) -> PKMeansResult
    with centroids replicated.
    """
    if params.reseed_empty:
        # the farthest in-subset point is shard-local state; the global
        # reseed would need a cross-shard argmax collective (not worth the
        # extra per-iteration all-reduce in the baseline we are measuring)
        raise NotImplementedError(
            "reseed_empty is not supported in pkmeans_sharded; reseeding "
            "targets the per-subset solvers (kmeans/ipkmeans)")

    def solve(points, init_centroids, mask):
        def cond(carry):
            c, _, it, shift = carry
            return jnp.logical_and(it < params.max_iters, shift > params.tol)

        def body(carry):
            c, _, it, _ = carry
            sums, counts, _ = _local_stats(points, c, mask, params.backend)
            sums = jax.lax.psum(sums, axis_names)      # <- the "MapReduce job"
            counts = jax.lax.psum(counts, axis_names)
            new_c = ref.divide_or_keep(sums, counts,
                                       c.astype(sums.dtype)).astype(c.dtype)
            return (new_c, c, it + 1, metrics.centroid_shift(new_c, c))

        init = (init_centroids, init_centroids, jnp.int32(0),
                jnp.asarray(jnp.inf))
        final_c, _, iters, shift = jax.lax.while_loop(cond, body, init)
        _, _, local_sse = _local_stats(points, final_c, mask, params.backend)
        total = jax.lax.psum(local_sse, axis_names)
        return PKMeansResult(final_c, total, iters, shift <= params.tol)

    shard_axes = P(axis_names)
    return shard_map(
        solve, mesh=mesh,
        in_specs=(shard_axes, P(), shard_axes),
        out_specs=PKMeansResult(P(), P(), P(), P()),
        check_vma=False)
