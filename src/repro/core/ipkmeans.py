"""IPKMeans — the paper's contribution, as a composable JAX pipeline.

Three stages (Section 2):
  S1  partition_dataset : k-d tree median splits + labeling  (O(log n) rounds)
  S2  per-subset k-means: M independent Lloyd solvers to convergence —
      *one* program launch, zero collectives inside the loops (the paper's
      "one single MapReduce job with much more reducers")
  S3  merge             : hierarchical midpoint merging or min-ASSE selection

``ipkmeans`` is the single-process reference; ``ipkmeans_distributed`` runs
S2 under ``shard_map`` with subsets sharded over the mesh, which is the
production path (each device == a stack of Hadoop reducers).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import kdtree, merge, metrics
from repro.core.kmeans import KMeansParams, KMeansResult, kmeans_batched


@dataclasses.dataclass(frozen=True)
class IPKMeansConfig:
    num_clusters: int                       # K — final clusters wanted
    num_subsets: int                        # M — parallel "reducers"
    partition: str = "kd_axis"              # 'kd_axis' | 'kd_random' | 'random'
    merge: str = "min_asse"                 # 'min_asse' | 'hierarchical'
    pack: str = "scatter"                   # 'scatter' | 'sorted' | 'a2a'
    leaf_capacity: int | None = None        # default: num_subsets (paper)
    label_axis: int = 0
    kmeans: KMeansParams = KMeansParams()

    def with_backend(self, backend: str) -> "IPKMeansConfig":
        """Same config, different Lloyd engine ('jnp' | 'pallas' | 'fused' |
        'resident' | 'batched' | 'tuned' — any name in the
        ``kernels.engine`` registry).

        The engine is the hot-path choice every S2 reducer executes; this
        helper keeps it switchable without re-spelling the whole config.
        ``batched`` is the intended S2 engine on TPU: subsets are sized to
        fit VMEM, and each device's whole reducer STACK lowers to one
        pipelined multi-group kernel launch (``resident`` runs the same
        per-subset loop but one grid step per reducer, serialized).
        """
        return dataclasses.replace(
            self, kmeans=self.kmeans._replace(backend=backend))

    def with_prune(self, prune: str) -> "IPKMeansConfig":
        """Same config, different pruning mode ('none' | 'bounds').

        ``"bounds"`` turns on Hamerly-style bound-gated block skipping
        inside the whole-solve kernels' convergence loops (the
        ``resident``/``batched``/``tuned`` engines): late iterations of each
        S2 reducer skip the score pass for point blocks whose assignments
        provably cannot change.  Results are bit-for-bit identical to
        ``"none"`` — this is a pure perf knob, safe to flip on any config.
        """
        return dataclasses.replace(
            self, kmeans=self.kmeans._replace(prune=prune))

    def with_init(self, init: str) -> "IPKMeansConfig":
        """Same config, different seeding strategy ('given' | 'sample' |
        'kmeans++' | 'kmeans||').

        Non-``"given"`` strategies let ``ipkmeans``/``ipkmeans_distributed``
        derive the shared per-reducer seeds themselves (from their ``key``)
        instead of taking externally supplied ``init_centroids``.
        ``"kmeans||"`` is the oversampled Bahmani et al. init run as fused
        kernel round sweeps (``core/init.py`` / ``kernels/init.py``) —
        better seeds mean fewer Lloyd iterations per reducer, i.e. fewer
        on-chip while-loop trips per megakernel launch.
        """
        from repro.core.init import INIT_METHODS
        if init not in INIT_METHODS:
            raise ValueError(f"unknown init: {init!r} "
                             f"(expected one of {INIT_METHODS})")
        return dataclasses.replace(
            self, kmeans=self.kmeans._replace(init=init))

    @property
    def init(self) -> str:
        """The seeding strategy (lives on the nested ``KMeansParams``)."""
        return self.kmeans.init

    def subset_capacity(self, n: int) -> int:
        """Static bound on points per subset (tensor packing size)."""
        if self.partition == "random":
            return -(-n // self.num_subsets)                   # ceil
        cap = self.leaf_capacity or self.num_subsets
        depth = kdtree.required_depth(n, cap)
        # leaves hold <= ceil(n / 2^depth) points; labels wrap mod M, so a
        # leaf contributes <= ceil(max_leaf / M) points to each subset
        max_leaf = -(-n // (2 ** depth))
        return (2 ** depth) * (-(-max_leaf // self.num_subsets))


class IPKMeansResult(NamedTuple):
    centroids: jnp.ndarray                  # (K, d) final centroids
    sse: jnp.ndarray                        # () SSE over the FULL dataset
    intermediate: jnp.ndarray               # (M, K, d) per-subset centroids
    asses: jnp.ndarray                      # (M,) per-subset ASSE
    subset_iters: jnp.ndarray               # (M,) Lloyd iterations per subset
    kd_depth: int                           # static: tree levels ("jobs")


def _partition_and_pack(points, key, cfg: IPKMeansConfig,
                        mesh=None, axis_names=None):
    """S1: partition, then route each subset to its reducer.

    The shuffle strategy is ``cfg.pack`` (§Perf C2/C3 — previously
    reachable only from the kmeans_dryrun CLI):

      * ``scatter`` — the reference scatter-pack; always valid.
      * ``sorted``  — one sort + reshape, no scatter (GSPMD lowers the
        scatter as a dataset-sized all-reduce; the sort+gather moves the
        data once).  Requires every subset to hold exactly ``capacity``
        points (``n == M * capacity``, the static precondition the kernel
        itself asserts) — otherwise falls back to ``scatter``.
      * ``a2a``     — explicit shard_map all_to_all shuffle; needs a mesh
        (so the single-process :func:`ipkmeans` falls back to ``scatter``),
        and itself falls back when M or n don't divide over the mesh.
    """
    if cfg.pack not in ("scatter", "sorted", "a2a"):
        raise ValueError(f"unknown pack: {cfg.pack!r} "
                         f"(expected 'scatter' | 'sorted' | 'a2a')")
    part = kdtree.partition_dataset(
        points, key, cfg.num_subsets,
        leaf_capacity=cfg.leaf_capacity,
        strategy=cfg.partition, label_axis=cfg.label_axis)
    n = points.shape[0]
    capacity = cfg.subset_capacity(n)
    if cfg.pack == "sorted" and n == cfg.num_subsets * capacity:
        subsets, masks = kdtree.pack_subsets_sorted(
            points, part.subset_ids, cfg.num_subsets, capacity)
    elif cfg.pack == "a2a" and mesh is not None:
        subsets, masks = kdtree.pack_subsets_a2a(
            points, part.subset_ids, cfg.num_subsets, capacity,
            mesh, axis_names)
    else:
        subsets, masks = kdtree.pack_subsets(
            points, part.subset_ids, cfg.num_subsets, capacity)
    return part, subsets, masks


def _merge_stage(points, res: KMeansResult, cfg: IPKMeansConfig):
    m, k, d = res.centroids.shape
    if cfg.merge == "min_asse":
        final = merge.min_asse_merge(res.centroids, res.asse)
    elif cfg.merge == "hierarchical":
        final = merge.hierarchical_merge(res.centroids.reshape(m * k, d), k)
    else:
        raise ValueError(f"unknown merge: {cfg.merge}")
    return final, metrics.sse(points, final)


def _resolve_init_stage(points, init_centroids, key, cfg: IPKMeansConfig,
                        mesh=None, axis_names=("data",)):
    """Seeding stage shared by both entry points: when ``cfg.init`` is not
    ``"given"``, derive the shared per-reducer seeds on host (splitting the
    key so partitioning randomness is unchanged only in the "given" path)
    and hand back a ``"given"`` config for the jitted core.  With a mesh,
    the k-means|| round sweeps run per-shard under ``shard_map``."""
    if cfg.init == "given":
        if init_centroids is None:
            raise ValueError('cfg.init="given" needs init_centroids')
        return points, init_centroids, key, cfg
    from repro.core import init as init_mod
    from repro.core.kmeans import _init_backend
    key, ik = jax.random.split(key)
    init_centroids = init_mod.resolve_init(
        points, ik, cfg.num_clusters, cfg.init,
        backend=_init_backend(cfg.kmeans.backend),
        mesh=mesh, axis_names=tuple(axis_names))
    return points, init_centroids, key, cfg.with_init("given")


def ipkmeans(points: jnp.ndarray,
             init_centroids: jnp.ndarray | None,
             key: jax.Array,
             cfg: IPKMeansConfig) -> IPKMeansResult:
    """Single-process IPKMeans (also the distributed path's oracle).

    With ``cfg.init != "given"`` the shared per-reducer seeds are derived
    here on host (k-means|| rounds are a host loop over fused kernel
    sweeps) before the jitted S1-S3 core runs; ``init_centroids`` may then
    be ``None``.
    """
    points, init_centroids, key, cfg = _resolve_init_stage(
        points, init_centroids, key, cfg)
    return _ipkmeans_core(points, init_centroids, key, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def _ipkmeans_core(points: jnp.ndarray,
                   init_centroids: jnp.ndarray,
                   key: jax.Array,
                   cfg: IPKMeansConfig) -> IPKMeansResult:
    part, subsets, masks = _partition_and_pack(points, key, cfg)
    res = kmeans_batched(subsets, masks, init_centroids, cfg.kmeans)
    final, total_sse = _merge_stage(points, res, cfg)
    return IPKMeansResult(centroids=final, sse=total_sse,
                          intermediate=res.centroids, asses=res.asse,
                          subset_iters=res.iters, kd_depth=part.depth)


def ipkmeans_distributed(points: jnp.ndarray,
                         init_centroids: jnp.ndarray | None,
                         key: jax.Array,
                         cfg: IPKMeansConfig,
                         mesh,
                         axis_names: tuple[str, ...] = ("data",)) -> IPKMeansResult:
    """Production IPKMeans on a device mesh.

    S1 runs jit-sharded (sorts partition fine under SPMD); S2 runs under
    ``shard_map`` with the subset axis sharded over ``axis_names`` so each
    device drives its own ``lax.while_loop`` with NO collectives — the
    communication-avoidance that defines the paper.  The shard_map body is
    ``kmeans_batched``, so ``cfg.kmeans.backend`` picks how each device
    runs its local stack: per-subset engines vmap (serialized grid), while
    ``"batched"`` lowers the whole per-device stack to one pipelined
    megakernel launch.  S3 is O(K*M) and runs replicated.

    ``num_subsets`` must be a multiple of the mesh size along ``axis_names``.

    With ``cfg.init != "given"``, the seeding stage runs first: each
    k-means|| round's fused sweep executes per-shard under ``shard_map``
    (points sharded over ``axis_names``, the round's candidates replicated,
    partial potentials psum'd), and the gathered candidates recluster on
    host — the same rounds the single-host path runs, so on a 1-device
    mesh the seeds (and hence the whole solve) match ``ipkmeans`` exactly.
    """
    points, init_centroids, key, cfg = _resolve_init_stage(
        points, init_centroids, key, cfg, mesh=mesh, axis_names=axis_names)
    n_dev = 1
    for a in axis_names:
        n_dev *= mesh.shape[a]
    if cfg.num_subsets % n_dev:
        raise ValueError(
            f"num_subsets={cfg.num_subsets} not divisible by mesh size {n_dev}")

    part, subsets, masks = _partition_and_pack(points, key, cfg,
                                               mesh=mesh,
                                               axis_names=axis_names)

    def s2_body(sub, msk):                       # per-device stack of reducers
        return kmeans_batched(sub, msk, init_centroids, cfg.kmeans)

    spec = P(axis_names)
    s2 = shard_map(
        s2_body, mesh=mesh, in_specs=(spec, spec),
        out_specs=KMeansResult(spec, spec, spec, spec, spec),
        check_vma=False)
    res = s2(subsets, masks)
    final, total_sse = _merge_stage(points, res, cfg)
    return IPKMeansResult(centroids=final, sse=total_sse,
                          intermediate=res.centroids, asses=res.asse,
                          subset_iters=res.iters, kd_depth=part.depth)
