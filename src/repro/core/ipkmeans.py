"""IPKMeans — the paper's contribution, as a composable JAX pipeline.

Three stages (Section 2):
  S1  partition_dataset : k-d tree median splits + labeling  (O(log n) rounds)
  S2  per-subset k-means: M independent Lloyd solvers to convergence —
      *one* program launch, zero collectives inside the loops (the paper's
      "one single MapReduce job with much more reducers")
  S3  merge             : hierarchical midpoint merging or min-ASSE selection

``ipkmeans`` is the single-process reference; ``ipkmeans_distributed`` runs
S2 under ``shard_map`` with subsets sharded over the mesh, which is the
production path (each device == a stack of Hadoop reducers).

Two scale-out layers sit on top of the single mesh:

  * **pods** — ``ipkmeans_distributed(..., pod_axis="pods")`` on a
    ``(pods x devices)`` mesh (``distributed/sharding.kmeans_pod_mesh``)
    additionally shards each subset's POINTS over the slow cross-host axis.
    Each Lloyd iteration then reduces per-cluster (sums, counts) across
    pods — the one DCN cost of the whole solve — and ``cfg.reduce``
    chooses how: ``"exact"`` (f32 psum) or ``"int8ef"`` (int8
    error-feedback quantization via ``distributed/compress.ef_allreduce``,
    the quantization residual carried across iterations so the Lloyd fixed
    point stays unbiased while the wire payload drops ~4x).
  * **fault tolerance** — ``ipkmeans_recoverable`` drives the S2 stacks
    under the heartbeat Coordinator (``distributed/runtime``): a worker
    that misses its heartbeat is evicted and ONLY its own reducer stack
    re-solves from its last centroid snapshot.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import kdtree, merge, metrics
from repro.core.kmeans import KMeansParams, KMeansResult, kmeans_batched
from repro.kernels import engine as engines
from repro.kernels import ref

REDUCE_MODES = ("exact", "int8ef")
S1_MODES = ("auto", "sort", "histogram")


@dataclasses.dataclass(frozen=True)
class IPKMeansConfig:
    num_clusters: int                       # K — final clusters wanted
    num_subsets: int                        # M — parallel "reducers"
    partition: str = "kd_axis"              # 'kd_axis' | 'kd_random' | 'random'
    merge: str = "min_asse"                 # 'min_asse' | 'hierarchical'
    pack: str = "scatter"                   # 'scatter' | 'sorted' | 'a2a'
    reduce: str = "exact"                   # 'exact' | 'int8ef': cross-pod
                                            # stats reduction (pod_axis only)
    s1: str = "auto"                        # 'auto' | 'sort' | 'histogram':
                                            # tree build + labeling machinery
    leaf_capacity: int | None = None        # default: num_subsets (paper)
    label_axis: int = 0
    kmeans: KMeansParams = KMeansParams()

    def __post_init__(self):
        if self.reduce not in REDUCE_MODES:
            raise ValueError(f"unknown reduce: {self.reduce!r} "
                             f"(expected one of {REDUCE_MODES})")
        if self.s1 not in S1_MODES:
            raise ValueError(f"unknown s1: {self.s1!r} "
                             f"(expected one of {S1_MODES})")

    def with_s1(self, s1: str) -> "IPKMeansConfig":
        """Same config, different S1 machinery ('auto' | 'sort' | 'histogram').

        ``"sort"`` is the paper-faithful lexsort build + exact-key labeling;
        ``"histogram"`` is the radix-histogram build plus the bucketed-rank
        labeler — the pair whose cross-shard traffic is O(R * 256) summaries
        per round, and hence the only pair that can run sharded over the pod
        mesh.  ``"auto"`` (default) picks ``"histogram"`` when
        ``ipkmeans_distributed`` is given a ``pod_axis`` (where the sort
        paths would lower as dataset-sized DCN collectives) and ``"sort"``
        everywhere else, preserving the established single-mesh outputs.
        """
        return dataclasses.replace(self, s1=s1)

    def with_reduce(self, reduce: str) -> "IPKMeansConfig":
        """Same config, different cross-pod reduction ('exact' | 'int8ef').

        Only ``ipkmeans_distributed`` with a ``pod_axis`` performs a
        cross-host reduction, so only there does this knob act: ``"int8ef"``
        quantizes each pod's per-cluster (sums, counts) to int8 with
        per-row scales before the DCN all-gather and carries the
        quantization residual across Lloyd iterations
        (``distributed/compress.ef_allreduce`` — error feedback keeps the
        fixed point unbiased).  The single-process/single-mesh paths have
        no DCN hop and ignore it.
        """
        return dataclasses.replace(self, reduce=reduce)

    def with_backend(self, backend: str) -> "IPKMeansConfig":
        """Same config, different Lloyd engine ('jnp' | 'pallas' | 'fused' |
        'resident' | 'batched' | 'tuned' — any name in the
        ``kernels.engine`` registry).

        The engine is the hot-path choice every S2 reducer executes; this
        helper keeps it switchable without re-spelling the whole config.
        ``batched`` is the intended S2 engine on TPU: subsets are sized to
        fit VMEM, and each device's whole reducer STACK lowers to one
        pipelined multi-group kernel launch (``resident`` runs the same
        per-subset loop but one grid step per reducer, serialized).
        """
        return dataclasses.replace(
            self, kmeans=self.kmeans._replace(backend=backend))

    def with_prune(self, prune: str) -> "IPKMeansConfig":
        """Same config, different pruning mode ('none' | 'bounds').

        ``"bounds"`` turns on Hamerly-style bound-gated block skipping
        inside the whole-solve kernels' convergence loops (the
        ``resident``/``batched``/``tuned`` engines): late iterations of each
        S2 reducer skip the score pass for point blocks whose assignments
        provably cannot change.  Results are bit-for-bit identical to
        ``"none"`` — this is a pure perf knob, safe to flip on any config.
        """
        return dataclasses.replace(
            self, kmeans=self.kmeans._replace(prune=prune))

    def with_init(self, init: str) -> "IPKMeansConfig":
        """Same config, different seeding strategy ('given' | 'sample' |
        'kmeans++' | 'kmeans||').

        Non-``"given"`` strategies let ``ipkmeans``/``ipkmeans_distributed``
        derive the shared per-reducer seeds themselves (from their ``key``)
        instead of taking externally supplied ``init_centroids``.
        ``"kmeans||"`` is the oversampled Bahmani et al. init run as fused
        kernel round sweeps (``core/init.py`` / ``kernels/init.py``) —
        better seeds mean fewer Lloyd iterations per reducer, i.e. fewer
        on-chip while-loop trips per megakernel launch.
        """
        from repro.core.init import INIT_METHODS
        if init not in INIT_METHODS:
            raise ValueError(f"unknown init: {init!r} "
                             f"(expected one of {INIT_METHODS})")
        return dataclasses.replace(
            self, kmeans=self.kmeans._replace(init=init))

    @property
    def init(self) -> str:
        """The seeding strategy (lives on the nested ``KMeansParams``)."""
        return self.kmeans.init

    def subset_capacity(self, n: int) -> int:
        """Static bound on points per subset (tensor packing size)."""
        if self.partition == "random":
            return -(-n // self.num_subsets)                   # ceil
        cap = self.leaf_capacity or self.num_subsets
        depth = kdtree.required_depth(n, cap)
        # leaves hold <= ceil(n / 2^depth) points; labels wrap mod M, so a
        # leaf contributes <= ceil(max_leaf / M) points to each subset
        max_leaf = -(-n // (2 ** depth))
        return (2 ** depth) * (-(-max_leaf // self.num_subsets))


class IPKMeansResult(NamedTuple):
    centroids: jnp.ndarray                  # (K, d) final centroids
    sse: jnp.ndarray                        # () SSE over the FULL dataset
    intermediate: jnp.ndarray               # (M, K, d) per-subset centroids
    asses: jnp.ndarray                      # (M,) per-subset ASSE
    subset_iters: jnp.ndarray               # (M,) Lloyd iterations per subset
    kd_depth: int                           # static: tree levels ("jobs")


def _check_pack_complete(n: int, masks, dropped, pack: str) -> None:
    """Raise if the pack lost points (satellite of §Perf C3: a dropped point
    silently biases every downstream centroid).  Skipped under tracing —
    the distributed entry points run the pack eagerly, so production packs
    are always checked."""
    lost = dropped if dropped is not None else (
        jnp.int32(n) - masks.sum(dtype=jnp.int32))
    if isinstance(lost, jax.core.Tracer):
        return
    lost = int(lost)
    if lost:
        raise ValueError(
            f"pack={pack!r} dropped {lost} of {n} points (packed mask counts "
            f"{n - lost}): subset capacity or a2a slack is too small for "
            "this partition's skew")


def _partition_and_pack(points, key, cfg: IPKMeansConfig,
                        mesh=None, axis_names=None, pod_axis=None):
    """S1: partition, then route each subset to its reducer.

    With a ``mesh`` and ``cfg.s1`` resolving to ``"histogram"``, the whole
    stage runs sharded: the tree build and the labeler exchange only
    O(R * 256) histogram summaries per radix round (points sharded over
    ``(pod_axis,) + axis_names``), and the a2a pack routes each point to
    its subset's owner column inside its own pod — zero DCN payload.
    ``cfg.s1="auto"`` keeps the sort machinery everywhere except the
    pod path, where sorts would lower as dataset-sized DCN collectives.

    The shuffle strategy is ``cfg.pack`` (§Perf C2/C3 — previously
    reachable only from the kmeans_dryrun CLI):

      * ``scatter`` — the reference scatter-pack; always valid.
      * ``sorted``  — one sort + reshape, no scatter (GSPMD lowers the
        scatter as a dataset-sized all-reduce; the sort+gather moves the
        data once).  Requires every subset to hold exactly ``capacity``
        points (``n == M * capacity``, the static precondition the kernel
        itself asserts) — otherwise falls back to ``scatter``.
      * ``a2a``     — explicit shard_map all_to_all shuffle; needs a mesh
        (so the single-process :func:`ipkmeans` falls back to ``scatter``
        with a warning), and itself warns + falls back when M or n don't
        divide over the mesh.

    Every path's mask count is checked against ``n`` when running eagerly
    (:func:`_check_pack_complete`); the returned subsets' capacity axis is
    always a multiple of the pod count so the pod path can shard it.
    """
    if cfg.pack not in ("scatter", "sorted", "a2a"):
        raise ValueError(f"unknown pack: {cfg.pack!r} "
                         f"(expected 'scatter' | 'sorted' | 'a2a')")
    s1 = cfg.s1
    if s1 == "auto":
        s1 = "histogram" if pod_axis is not None else "sort"
    point_axes = ((pod_axis,) + tuple(axis_names)) if pod_axis \
        else tuple(axis_names or ())
    shard_s1 = (s1 == "histogram" and mesh is not None
                and cfg.partition == "kd_axis")
    part = kdtree.partition_dataset(
        points, key, cfg.num_subsets,
        leaf_capacity=cfg.leaf_capacity,
        strategy=cfg.partition, label_axis=cfg.label_axis,
        builder="histogram" if s1 == "histogram" else "sort",
        labeler="histogram" if s1 == "histogram" else "sort",
        mesh=mesh if shard_s1 else None,
        axis_names=point_axes if shard_s1 else None)
    n = points.shape[0]
    capacity = cfg.subset_capacity(n)
    n_pods = mesh.shape[pod_axis] if (mesh is not None and pod_axis) else 1
    dropped = None
    if cfg.pack == "sorted" and n == cfg.num_subsets * capacity:
        subsets, masks = kdtree.pack_subsets_sorted(
            points, part.subset_ids, cfg.num_subsets, capacity)
    elif cfg.pack == "a2a" and mesh is not None:
        if n_pods > 1:
            # the pod a2a shards capacity over pods, and a pod's share of a
            # subset fluctuates around capacity/n_pods — provision the
            # per-pod slice with the same slack-plus-4-sigma headroom the
            # send buffers use (masked rows are free for the solve)
            mean = capacity / n_pods
            cap_loc = max(8, -(-int(mean * 1.3 + 4 * math.sqrt(mean))
                               // 8) * 8)
            capacity = cap_loc * n_pods
        subsets, masks, dropped = kdtree.pack_subsets_a2a(
            points, part.subset_ids, cfg.num_subsets, capacity,
            mesh, axis_names, pod_axis=pod_axis)
    else:
        if cfg.pack == "a2a":
            warnings.warn(
                "pack='a2a' needs a device mesh; using the scatter pack "
                "(all-reduce-shaped collective) instead",
                RuntimeWarning, stacklevel=2)
        subsets, masks = kdtree.pack_subsets(
            points, part.subset_ids, cfg.num_subsets, capacity)
    _check_pack_complete(n, masks, dropped, cfg.pack)
    pad = -subsets.shape[1] % n_pods
    if pad:
        subsets = jnp.pad(subsets, ((0, 0), (0, pad), (0, 0)))
        masks = jnp.pad(masks, ((0, 0), (0, pad)))
    return part, subsets, masks


def _merge_stage(points, res: KMeansResult, cfg: IPKMeansConfig):
    m, k, d = res.centroids.shape
    if cfg.merge == "min_asse":
        final = merge.min_asse_merge(res.centroids, res.asse)
    elif cfg.merge == "hierarchical":
        final = merge.hierarchical_merge(res.centroids.reshape(m * k, d), k)
    else:
        raise ValueError(f"unknown merge: {cfg.merge}")
    return final, metrics.sse(points, final)


def _resolve_init_stage(points, init_centroids, key, cfg: IPKMeansConfig,
                        mesh=None, axis_names=("data",)):
    """Seeding stage shared by both entry points: when ``cfg.init`` is not
    ``"given"``, derive the shared per-reducer seeds on host (splitting the
    key so partitioning randomness is unchanged only in the "given" path)
    and hand back a ``"given"`` config for the jitted core.  With a mesh,
    the k-means|| round sweeps run per-shard under ``shard_map``."""
    if cfg.init == "given":
        if init_centroids is None:
            raise ValueError('cfg.init="given" needs init_centroids')
        return points, init_centroids, key, cfg
    from repro.core import init as init_mod
    from repro.core.kmeans import _init_backend
    key, ik = jax.random.split(key)
    init_centroids = init_mod.resolve_init(
        points, ik, cfg.num_clusters, cfg.init,
        backend=_init_backend(cfg.kmeans.backend),
        mesh=mesh, axis_names=tuple(axis_names))
    return points, init_centroids, key, cfg.with_init("given")


def ipkmeans(points: jnp.ndarray,
             init_centroids: jnp.ndarray | None,
             key: jax.Array,
             cfg: IPKMeansConfig) -> IPKMeansResult:
    """Single-process IPKMeans (also the distributed path's oracle).

    With ``cfg.init != "given"`` the shared per-reducer seeds are derived
    here on host (k-means|| rounds are a host loop over fused kernel
    sweeps) before the jitted S1-S3 core runs; ``init_centroids`` may then
    be ``None``.
    """
    points, init_centroids, key, cfg = _resolve_init_stage(
        points, init_centroids, key, cfg)
    return _ipkmeans_core(points, init_centroids, key, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def _ipkmeans_core(points: jnp.ndarray,
                   init_centroids: jnp.ndarray,
                   key: jax.Array,
                   cfg: IPKMeansConfig) -> IPKMeansResult:
    part, subsets, masks = _partition_and_pack(points, key, cfg)
    res = kmeans_batched(subsets, masks, init_centroids, cfg.kmeans)
    final, total_sse = _merge_stage(points, res, cfg)
    return IPKMeansResult(centroids=final, sse=total_sse,
                          intermediate=res.centroids, asses=res.asse,
                          subset_iters=res.iters, kd_depth=part.depth)


def _s2_cross_pod_solve(sub, msk, init_centroids, cfg: IPKMeansConfig,
                        pod_axis: str):
    """Per-program S2 body when each subset's points shard over a pod axis.

    ``sub``/``msk`` are the program's local slices — ``(M_loc, S_loc, d)`` /
    ``(M_loc, S_loc)`` with the subset axis over the in-pod devices and the
    point axis over pods.  Every Lloyd iteration computes local per-cluster
    stats with ``engine.step`` and reduces them over ``pod_axis``: f32 psum
    (``reduce="exact"``) or int8 error-feedback all-gather
    (``reduce="int8ef"``, per-row scales; the EFState residual rides the
    while-loop carry so the quantization error feeds back into the next
    iteration and the fixed point stays unbiased).  All pods receive the
    SAME reduced stats, so per-subset convergence decisions — and therefore
    the loop trip counts — stay consistent across pods without extra
    synchronization.  Returns ``(centroids (M_loc,k,d) f32, sse (M_loc,),
    asse (M_loc,), iters (M_loc,) i32, converged (M_loc,) bool)`` mirroring
    the host solve's semantics (divide-or-keep, max-shift stop criterion,
    final-centroid scoring pass).

    int8ef convergence: a quantized reduction can never place a centroid
    closer to the exact fixed point than the wire precision, so a ``tol``
    tighter than the quantization noise floor would spin to ``max_iters``
    chasing jitter.  Each iteration therefore widens the per-subset stop
    threshold to ``max(tol, noise floor)``, the floor derived from the
    dequantization error bound ``ef_allreduce`` reports: once the observed
    shift is inside the floor, further movement is indistinguishable from
    noise and the lane stops (converged=True — it IS at the fixed point to
    wire precision).
    """
    from repro.distributed import compress
    params = cfg.kmeans
    engine = engines.get_engine(params.backend)
    m_loc = sub.shape[0]
    k, d = init_centroids.shape
    w = msk.astype(sub.dtype)
    step_m = jax.vmap(engine.step)

    c0 = jnp.broadcast_to(init_centroids.astype(jnp.float32), (m_loc, k, d))
    stats0 = {"sums": jnp.zeros((m_loc, k, d), jnp.float32),
              "counts": jnp.zeros((m_loc, k), jnp.float32)}
    # per-row scales: one per (subset, cluster) sums row, one per subset
    # counts vector — empty clusters' all-zero rows round-trip to exact
    # zeros instead of inheriting a big cluster's scale
    axes_spec = {"sums": -1, "counts": -1}
    ef0 = compress.init_ef(stats0)
    tol0 = jnp.full((m_loc,), params.tol, jnp.float32)

    def cond(carry):
        c, iters, shift, eff_tol, ef = carry
        return jnp.any(jnp.logical_and(iters < params.max_iters,
                                       shift > eff_tol))

    def body(carry):
        c, iters, shift, eff_tol, ef = carry
        active = jnp.logical_and(iters < params.max_iters,
                                 shift > eff_tol)
        sums, counts, _ = step_m(sub, c, w)
        stats = {"sums": sums, "counts": counts}
        if cfg.reduce == "int8ef":
            red, ef, err = compress.ef_allreduce(
                stats, ef, pod_axis, axes=axes_spec,
                return_error_bound=True)
        else:
            red = jax.lax.psum(stats, pod_axis)
            err = None
        cnt = jnp.maximum(red["counts"], 0.0)
        upd = jax.vmap(ref.divide_or_keep)(red["sums"], cnt, c)
        if err is not None:
            # per-cluster centroid noise from the quantized (sums, counts):
            # |S~/N~ - S/N| <= (err_S + |c|*err_N) / (N - err_N) per
            # coordinate.  Empty clusters are excluded — divide_or_keep
            # pins them, so they contribute no jitter (their all-zero sums
            # rows quantize exactly anyway).
            e_s = err["sums"][..., 0]                         # (m, k)
            e_n = err["counts"]                               # (m, 1)
            cmax = jnp.max(jnp.abs(upd), axis=-1)             # (m, k)
            noise = jnp.where(
                cnt > 0.0,
                (e_s + cmax * e_n) / jnp.maximum(cnt - e_n, 1.0), 0.0)
            floor = jnp.sqrt(float(d)) * jnp.max(noise, axis=-1)
            eff_tol = jnp.where(active,
                                jnp.maximum(tol0, floor), eff_tol)
        new_c = jnp.where(active[:, None, None], upd, c)
        new_shift = jnp.where(
            active, jax.vmap(metrics.centroid_shift)(new_c, c), shift)
        return (new_c, iters + active.astype(jnp.int32), new_shift,
                eff_tol, ef)

    final_c, iters, shift, eff_tol, _ = jax.lax.while_loop(
        cond, body,
        (c0, jnp.zeros((m_loc,), jnp.int32),
         jnp.full((m_loc,), jnp.inf, jnp.float32), tol0, ef0))
    # final scoring pass at the converged centroids, like engine.solve
    sse = jax.lax.psum(jax.vmap(engine.sse)(sub, final_c, w), pod_axis)
    cnt = jax.lax.psum(jnp.sum(w.astype(jnp.float32), axis=1), pod_axis)
    asse = jnp.where(cnt > 0.0, sse / jnp.maximum(cnt, 1.0), jnp.inf)
    return final_c, sse, asse, iters, shift <= eff_tol


def ipkmeans_distributed(points: jnp.ndarray,
                         init_centroids: jnp.ndarray | None,
                         key: jax.Array,
                         cfg: IPKMeansConfig,
                         mesh,
                         axis_names: tuple[str, ...] = ("data",),
                         pod_axis: str | None = None) -> IPKMeansResult:
    """Production IPKMeans on a device mesh.

    S1 runs jit-sharded on the single-mesh path (sorts partition fine under
    SPMD); with a ``pod_axis`` it instead runs under ``shard_map`` with
    points sharded over ``(pod_axis,) + axis_names`` and the histogram
    build/labeler exchanging only O(R * 256) summaries per radix round —
    no stage ever materializes the dataset on one shard (``cfg.s1``
    controls this; see :meth:`IPKMeansConfig.with_s1`).  S2 runs under
    ``shard_map`` with the subset axis sharded over ``axis_names`` so each
    device drives its own ``lax.while_loop`` with NO collectives — the
    communication-avoidance that defines the paper.  The shard_map body is
    ``kmeans_batched``, so ``cfg.kmeans.backend`` picks how each device
    runs its local stack: per-subset engines vmap (serialized grid), while
    ``"batched"`` lowers the whole per-device stack to one pipelined
    megakernel launch.  S3 is O(K*M) and runs replicated.

    ``num_subsets`` must be a multiple of the mesh size along ``axis_names``.

    With ``cfg.init != "given"``, the seeding stage runs first: each
    k-means|| round's fused sweep executes per-shard under ``shard_map``
    (points sharded over ``axis_names``, the round's candidates replicated,
    partial potentials psum'd), and the gathered candidates recluster on
    host — the same rounds the single-host path runs, so on a 1-device
    mesh the seeds (and hence the whole solve) match ``ipkmeans`` exactly.

    With ``pod_axis`` (a mesh axis NOT in ``axis_names``, e.g. from
    ``distributed/sharding.kmeans_pod_mesh``), each subset's points
    additionally shard over that slow cross-host axis and S2 switches to
    the cross-pod solve: one (sums, counts) reduction over ``pod_axis``
    per Lloyd iteration — the job's only DCN traffic — compressed per
    ``cfg.reduce`` (see :meth:`IPKMeansConfig.with_reduce`).  The subset
    capacity is padded up to a multiple of the pod count (masked rows,
    zero effect on the stats).
    """
    points, init_centroids, key, cfg = _resolve_init_stage(
        points, init_centroids, key, cfg, mesh=mesh, axis_names=axis_names)
    n_dev = 1
    for a in axis_names:
        n_dev *= mesh.shape[a]
    if cfg.num_subsets % n_dev:
        raise ValueError(
            f"num_subsets={cfg.num_subsets} not divisible by mesh size {n_dev}")
    if pod_axis is not None:
        if pod_axis in axis_names or pod_axis not in mesh.axis_names:
            raise ValueError(
                f"pod_axis={pod_axis!r} must be a mesh axis outside "
                f"axis_names={axis_names} (mesh has {mesh.axis_names})")
        if cfg.kmeans.reseed_empty:
            raise ValueError(
                "reseed_empty is not supported on the cross-pod S2 path: "
                "farthest-point selection needs a global view of the "
                "subset, but points are sharded over the pod axis")
    elif cfg.reduce != "exact":
        raise ValueError(
            f'reduce={cfg.reduce!r} needs pod_axis: compressed reduction '
            f'acts on the cross-pod stats all-reduce, and without a pod '
            f'axis S2 has no reduction at all (the paper\'s claim)')

    part, subsets, masks = _partition_and_pack(points, key, cfg,
                                               mesh=mesh,
                                               axis_names=axis_names,
                                               pod_axis=pod_axis)

    if pod_axis is None:
        def s2_body(sub, msk):                   # per-device stack of reducers
            return kmeans_batched(sub, msk, init_centroids, cfg.kmeans)

        spec = P(axis_names)
        s2 = shard_map(
            s2_body, mesh=mesh, in_specs=(spec, spec),
            out_specs=KMeansResult(spec, spec, spec, spec, spec),
            check_vma=False)
        res = s2(subsets, masks)
    else:
        def s2_pod_body(sub, msk):
            c, sse, asse, iters, conv = _s2_cross_pod_solve(
                sub, msk, init_centroids, cfg, pod_axis)
            return KMeansResult(centroids=c.astype(init_centroids.dtype),
                                sse=sse, asse=asse, iters=iters,
                                converged=conv)

        sub_spec = P(axis_names, pod_axis, None)
        msk_spec = P(axis_names, pod_axis)
        out = P(axis_names)      # replicated over pods: same reduced stats
        s2 = shard_map(
            s2_pod_body, mesh=mesh, in_specs=(sub_spec, msk_spec),
            out_specs=KMeansResult(out, out, out, out, out),
            check_vma=False)
        res = s2(subsets, masks)
    final, total_sse = _merge_stage(points, res, cfg)
    return IPKMeansResult(centroids=final, sse=total_sse,
                          intermediate=res.centroids, asses=res.asse,
                          subset_iters=res.iters, kd_depth=part.depth)


def ipkmeans_recoverable(points: jnp.ndarray,
                         init_centroids: jnp.ndarray | None,
                         key: jax.Array,
                         cfg: IPKMeansConfig,
                         *,
                         num_workers: int,
                         iters_per_round: int = 4,
                         snapshot_every: int = 2,
                         max_rounds: int = 200,
                         fail_at: dict | None = None,
                         rejoin_at: dict | None = None,
                         ft=None):
    """IPKMeans with S2 driven under the heartbeat-recovery protocol.

    The whole solve runs under ``distributed/runtime``'s Coordinator:
    ``num_workers`` workers own disjoint reducer stacks (contiguous slices
    of the M subsets — ``num_subsets`` must divide evenly), each round
    advances every unconverged subset by ``iters_per_round`` Lloyd
    iterations (Lloyd is Markov in the centroids, so the chunked advance
    replays exactly the unchunked iteration sequence), and per-stack
    centroid snapshots commit every ``snapshot_every`` rounds.  A worker
    that misses its heartbeat (``fail_at`` injects crashes as
    ``{round: worker_id}``) is evicted once ``ft.heartbeat_timeout``
    elapses and ONLY its own stack re-solves, from its last snapshot —
    survivors never recompute (assertable from the returned work log).

    Returns ``(IPKMeansResult, event log, work)`` — the result matches
    :func:`ipkmeans` on the same inputs; ``log``/``work`` come from
    :func:`repro.distributed.runtime.solve_stacks_with_recovery`.
    """
    from repro.distributed import runtime as rt
    if ft is None:
        ft = rt.FTConfig(heartbeat_timeout=2.5, min_workers=1)
    if cfg.num_subsets % num_workers:
        raise ValueError(f"num_subsets={cfg.num_subsets} not divisible by "
                         f"num_workers={num_workers}")
    points, init_centroids, key, cfg = _resolve_init_stage(
        points, init_centroids, key, cfg)
    part, subsets, masks = _partition_and_pack(points, key, cfg)
    params = cfg.kmeans
    engine = engines.get_engine(params.backend)
    per = cfg.num_subsets // num_workers
    k = init_centroids.shape[0]

    @jax.jit
    def _advance(sub, msk, cents, iters, conv):
        """Advance one stack by <= iters_per_round iterations per lane."""
        def one(p, m, c):
            return engine.solve(p, c, m.astype(p.dtype),
                                max_iters=iters_per_round, tol=params.tol,
                                reseed_empty=params.reseed_empty,
                                prune=params.prune)
        new_c, _, it, cv = jax.vmap(one)(sub, msk, cents)
        # freeze already-converged lanes so iteration counts stay faithful
        keep = conv[:, None, None]
        return (jnp.where(keep, cents, new_c.astype(jnp.float32)),
                iters + jnp.where(conv, 0, it),
                jnp.logical_or(conv, cv))

    def advance(stack_id, state):
        cents, iters, conv = state
        sl = slice(stack_id * per, (stack_id + 1) * per)
        cents, iters, conv = _advance(subsets[sl], masks[sl],
                                      cents, iters, conv)
        return (cents, iters, conv), bool(jnp.all(conv))

    c0 = jnp.broadcast_to(init_centroids.astype(jnp.float32),
                          (per, k, init_centroids.shape[1]))
    init_states = [(c0, jnp.zeros((per,), jnp.int32),
                    jnp.zeros((per,), bool)) for _ in range(num_workers)]
    states, log, work = rt.solve_stacks_with_recovery(
        advance, init_states, num_workers=num_workers,
        max_rounds=max_rounds, snapshot_every=snapshot_every,
        fail_at=fail_at, rejoin_at=rejoin_at, cfg=ft)

    cents = jnp.concatenate([s[0] for s in states])
    iters = jnp.concatenate([s[1] for s in states])
    conv = jnp.concatenate([s[2] for s in states])
    w = masks.astype(subsets.dtype)
    sse_m = jax.vmap(engine.sse)(subsets, cents, w)
    cnt = jnp.sum(masks.astype(jnp.float32), axis=1)
    asse = jnp.where(cnt > 0.0, sse_m / jnp.maximum(cnt, 1.0), jnp.inf)
    res = KMeansResult(centroids=cents.astype(init_centroids.dtype),
                       sse=sse_m, asse=asse, iters=iters, converged=conv)
    final, total_sse = _merge_stage(points, res, cfg)
    return (IPKMeansResult(centroids=final, sse=total_sse,
                           intermediate=res.centroids, asses=res.asse,
                           subset_iters=res.iters, kd_depth=part.depth),
            log, work)
