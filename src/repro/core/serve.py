"""Serving tier: batched nearest-centroid queries + mini-batch refresh.

The offline pipeline ends with a centroid set; production traffic then asks
"which centroid is nearest?" millions of times.  This module is that query
path.  Three problems shape it:

  * **Unbounded jit cache.**  Naively jitting the assign kernel per request
    shape compiles once per distinct batch size — a mixed-size request
    stream compiles forever.  The server rounds every batch up to a
    *bucket* (power-of-two by default) and keeps exactly one compiled
    ``ops.lloyd_assign_fused`` callable per bucket, so the cache is bounded
    by ``log2(max_bucket / min_bucket) + 1`` entries no matter the traffic.
  * **Padding must be free.**  Bucketing pads requests with zero rows.  The
    fused kernel's phase-1 argmin is per-row — a row's label/distance
    depends only on that row and the centroid tiles — so the real rows'
    results are bit-for-bit what the unpadded call would produce (under the
    same :class:`~repro.kernels.specs.KernelSpec` geometry); pad rows are
    sliced off before results leave the server.  Each bucket resolves its
    own tuned spec (``tuning.lookup_spec`` at the bucket shape), so
    autotuned winners reach the serving path the same way they reach the
    solvers.
  * **Centroids go stale.**  Arriving traffic drifts; re-running the full
    solve per refresh is exactly the cost the paper is built to avoid.
    :meth:`NearestCentroidServer.refresh` folds a sampled batch into the
    served centroids with one ``engine.update_minibatch`` sweep (Sculley
    mini-batch k-means; see ``ref.minibatch_merge``) — the centroids move,
    their shape does not, so no serving bucket ever retraces.

``launch/serve_kmeans.py`` wraps this in a steady-state dispatch loop and a
``--smoke`` CLI mirroring the LM serve harness; ``benchmarks/serve_bench.py``
measures p50/p99 latency + QPS per bucket and the refresh-quality gap.
"""
from __future__ import annotations

from collections import deque
from typing import NamedTuple

import jax.numpy as jnp

from repro.core.kmeans import KMeansParams, update_minibatch
from repro.kernels import ops, tuning


class BucketPolicy(NamedTuple):
    """How request batch sizes round up to compiled bucket sizes.

    ``kind="pow2"`` (default): the next power of two in
    ``[min_bucket, max_bucket]`` — the bounded-cache workhorse.
    ``kind="fixed"``: an explicit ascending ``ladder`` of bucket sizes (the
    smallest rung >= n wins); useful when traffic is known bimodal and two
    rungs beat six powers of two.  Requests larger than the top bucket are
    chunked by the server, so any n is servable under any policy.
    """
    kind: str = "pow2"            # 'pow2' | 'fixed'
    min_bucket: int = 8
    max_bucket: int = 4096
    ladder: tuple[int, ...] = ()  # kind='fixed' rungs, ascending

    def bucket_for(self, n: int) -> int:
        """Bucket size for an n-row chunk (n <= the top bucket)."""
        if n <= 0:
            raise ValueError(f"bucket_for needs n >= 1, got {n}")
        if self.kind == "fixed":
            if not self.ladder:
                raise ValueError("fixed bucket policy needs a ladder")
            for b in self.ladder:
                if n <= b:
                    return int(b)
            raise ValueError(f"n={n} exceeds top fixed bucket "
                             f"{self.ladder[-1]} (server chunks first)")
        if self.kind != "pow2":
            raise ValueError(f"unknown bucket policy kind: {self.kind!r}")
        b = self.min_bucket
        while b < n:
            b *= 2
        if b > self.max_bucket:
            raise ValueError(f"n={n} exceeds max_bucket={self.max_bucket} "
                             f"(server chunks first)")
        return int(b)

    @property
    def top(self) -> int:
        return int(self.ladder[-1]) if self.kind == "fixed" \
            else int(self.max_bucket)

    def buckets(self) -> tuple[int, ...]:
        """Every bucket this policy can ever emit, ascending — the jit
        cache's worst case."""
        if self.kind == "fixed":
            return tuple(int(b) for b in self.ladder)
        out, b = [], int(self.min_bucket)
        while b <= self.max_bucket:
            out.append(b)
            b *= 2
        return tuple(out)


class _Ticket(NamedTuple):
    ticket: int
    n: int


class NearestCentroidServer:
    """Persistent nearest-centroid endpoint over a served centroid set.

    Two ways in: :meth:`assign` answers one query batch synchronously
    (chunk -> bucket -> pad -> one compiled kernel call -> unpad);
    :meth:`submit` + :meth:`step` run the coalescing path — queued requests
    are packed together into one bucket per dispatch, so many small
    requests share a single kernel launch (the serve loop in
    ``launch/serve_kmeans.py`` drives this).

    ``trace_counts`` maps bucket -> number of jit traces; under any
    mixed-size request stream each bucket traces at most once (the
    boundedness contract ``tests/test_serve_kmeans.py`` asserts).
    """

    def __init__(self, centroids, counts=None, *,
                 policy: BucketPolicy = BucketPolicy(),
                 refresh_backend: str = "fused"):
        self.centroids = jnp.asarray(centroids)
        k = self.centroids.shape[0]
        self.counts = (jnp.zeros((k,), jnp.float32) if counts is None
                       else jnp.asarray(counts, jnp.float32))
        self.policy = policy
        self.refresh_backend = refresh_backend
        self.refresh_sse: list[float] = []    # per-refresh pre-update SSE
        self.trace_counts: dict[int, int] = {}
        self._fns: dict[int, object] = {}     # bucket -> compiled assign
        self._queue: deque = deque()          # (_Ticket, queries)
        self._results: dict[int, tuple] = {}
        self._next_ticket = 0

    # ------------------------------------------------------------ compile --
    def _fn_for(self, bucket: int):
        """The ONE compiled assign callable for this bucket (build on first
        use).  The tuned-spec lookup happens here, at the bucket shape, so
        a cache winner tuned for (bucket, d, k) serves every request the
        bucket absorbs.  Centroids are an argument, not a captured constant
        — refreshes change values, never shapes, so no retrace."""
        fn = self._fns.get(bucket)
        if fn is None:
            import jax
            d = self.centroids.shape[1]
            k = self.centroids.shape[0]
            spec = tuning.lookup_spec(bucket, d, k, self.centroids.dtype)

            def run(queries, centroids, _bucket=bucket, _spec=spec):
                # body executes at trace time only: counts retraces, and
                # therefore jit-cache entries, per bucket
                self.trace_counts[_bucket] = \
                    self.trace_counts.get(_bucket, 0) + 1
                return ops.lloyd_assign_fused(queries, centroids, spec=_spec)

            fn = jax.jit(run)
            self._fns[bucket] = fn
        return fn

    def _assign_bucketed(self, queries):
        """One chunk (rows <= top bucket) -> (labels, mind), via its bucket."""
        n = queries.shape[0]
        bucket = self.policy.bucket_for(n)
        padded = queries
        if bucket > n:
            pad = jnp.zeros((bucket - n, queries.shape[1]), queries.dtype)
            padded = jnp.concatenate([queries, pad], axis=0)
        labels, mind = self._fn_for(bucket)(padded, self.centroids)
        return labels[:n], mind[:n]

    # ------------------------------------------------------------- queries --
    def assign(self, queries):
        """Nearest centroids for one query batch -> (labels (n,) i32,
        mind (n,) f32).  Batches above the top bucket are chunked; every
        chunk rides an existing bucket, so arbitrary n never compiles a new
        kernel."""
        queries = jnp.asarray(queries)
        n = queries.shape[0]
        top = self.policy.top
        if n <= top:
            return self._assign_bucketed(queries)
        parts = [self._assign_bucketed(queries[i:i + top])
                 for i in range(0, n, top)]
        return (jnp.concatenate([p[0] for p in parts]),
                jnp.concatenate([p[1] for p in parts]))

    def submit(self, queries) -> int:
        """Queue a query batch for the next coalesced dispatch -> ticket."""
        queries = jnp.asarray(queries)
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((_Ticket(t, queries.shape[0]), queries))
        return t

    def step(self) -> list[int]:
        """One dispatch: pack queued requests into a single bucket and run
        ONE kernel call for all of them -> tickets completed.  Packing is
        FIFO up to the top bucket (an oversized head request is chunked by
        :meth:`assign`); leftover requests wait for the next step."""
        if not self._queue:
            return []
        taken, rows = [], 0
        top = self.policy.top
        while self._queue and (not taken
                               or rows + self._queue[0][0].n <= top):
            tk, q = self._queue.popleft()
            taken.append((tk, q))
            rows += tk.n
        labels, mind = self.assign(
            jnp.concatenate([q for _, q in taken], axis=0)
            if len(taken) > 1 else taken[0][1])
        off = 0
        done = []
        for tk, _ in taken:
            self._results[tk.ticket] = (labels[off:off + tk.n],
                                        mind[off:off + tk.n])
            off += tk.n
            done.append(tk.ticket)
        return done

    def result(self, ticket: int):
        """Pop a completed ticket's (labels, mind); KeyError if not ready."""
        return self._results.pop(ticket)

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------- refresh --
    def refresh(self, batch, weights=None):
        """Fold one sampled traffic batch into the served centroids (one
        fused ``update_minibatch`` sweep) -> this batch's SSE against the
        centroids it arrived at.  A rising ``refresh_sse`` series is the
        drift signal that says schedule a full re-solve (docs/serving.md).
        Values change, shapes don't: serving buckets never retrace."""
        mask = None if weights is None else weights
        new_c, new_counts, sse = update_minibatch(
            jnp.asarray(batch), self.centroids, self.counts, mask,
            params=KMeansParams(backend=self.refresh_backend))
        self.centroids = new_c
        self.counts = new_counts
        self.refresh_sse.append(float(sse))
        return sse
