"""Hadoop I/O + runtime cost model — reproduces Fig 5/6 analytically.

The paper measures Hadoop 1.2.1 byte counters (disk read/write) and wall
time.  On a TPU container neither exists, so the faithful reproduction uses a
calibrated model of the same quantities:

* byte counters follow the MapReduce dataflow of Dean & Ghemawat (Section 1
  of the paper): HDFS read -> map spill -> shuffle fetch -> HDFS write, per
  job;
* shuffle seconds are calibrated against the measurements the paper cites
  from [2] (Anchalia 2014): 4 s @ 50 k points, 30 s @ 500 k, 207 s @ 5 M —
  a least-squares linear fit through those points;
* job startup cost is a constant (Hadoop task JVM spin-up), configurable.

The model takes *measured* iteration counts from our JAX runs (PKMeans Lloyd
iterations, k-d tree depth), so "how many jobs" is empirical and only the
per-job cost is modeled.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# least-squares fit of shuffle seconds vs points through [2]'s measurements
_SHUFFLE_PTS = np.array([50_000.0, 500_000.0, 5_000_000.0])
_SHUFFLE_SEC = np.array([4.0, 30.0, 207.0])
_A = np.vstack([_SHUFFLE_PTS, np.ones(3)]).T
_SHUFFLE_SLOPE, _SHUFFLE_INTERCEPT = np.linalg.lstsq(_A, _SHUFFLE_SEC, rcond=None)[0]


@dataclasses.dataclass(frozen=True)
class HadoopCostModel:
    key_bytes: int = 8              # intermediate key (cluster / region id)
    value_overhead: int = 16        # record framing in SequenceFile
    float_bytes: int = 8            # Hadoop serializes doubles
    job_startup_sec: float = 3.0    # JVM + scheduling per job (debug mode)
    disk_bw: float = 100e6          # bytes/sec sequential disk
    # fixed bytes every job reads/writes regardless of data size: job.jar
    # staging, splits/conf files, task logs, _SUCCESS markers. Dominates at
    # paper-scale (3000 points = 78 KB of data vs ~hundreds of KB of
    # framework traffic per job) and is why bytes scale ~ #jobs there.
    job_fixed_read: int = 160_000
    job_fixed_write: int = 96_000

    def record_bytes(self, d: int) -> int:
        return d * self.float_bytes + self.value_overhead

    # ---------------- per-algorithm byte counters ----------------

    def pkmeans_bytes(self, n: int, d: int, k: int, iters: int):
        """PKMeans: one MapReduce job per Lloyd iteration (Algorithm 1)."""
        rec = self.record_bytes(d)
        kv = rec + self.key_bytes
        per_job_read = n * rec + n * kv + self.job_fixed_read
        per_job_write = n * kv + k * rec + self.job_fixed_write
        return {"read": iters * per_job_read,
                "write": iters * per_job_write,
                "jobs": iters}

    def ipkmeans_bytes(self, n: int, d: int, k: int, m: int, kd_depth: int):
        """IPKMeans: kd_depth tree jobs + 1 labeling job + 1 k-means job."""
        rec = self.record_bytes(d)
        read = write = 0
        # Algorithm 2: each level reads every point (+ region suffix) and
        # writes it back with one more suffix bit
        for level in range(kd_depth):
            kv_in = rec + self.key_bytes + (level + 7) // 8
            kv_out = rec + self.key_bytes + (level + 8) // 8
            read += n * kv_in + n * kv_in        # HDFS read + shuffle fetch
            write += n * kv_in + n * kv_out      # map spill + HDFS out
        # Algorithm 3: labeling job
        kv = rec + self.key_bytes
        read += 2 * n * kv
        write += 2 * n * kv
        # Algorithm 4: the single k-means job — reducers emit only centroids
        read += 2 * n * kv
        write += n * kv + m * k * (rec + self.key_bytes + self.float_bytes)
        jobs = kd_depth + 2
        read += jobs * self.job_fixed_read
        write += jobs * self.job_fixed_write
        return {"read": read, "write": write, "jobs": jobs}

    # ---------------- per-algorithm modeled seconds ----------------

    def shuffle_sec(self, n: int) -> float:
        return max(float(_SHUFFLE_SLOPE * n + _SHUFFLE_INTERCEPT), 0.0)

    def job_sec(self, n: int, bytes_moved: float) -> float:
        return (self.job_startup_sec + self.shuffle_sec(n)
                + bytes_moved / self.disk_bw)

    def pkmeans_sec(self, n: int, d: int, k: int, iters: int,
                    compute_sec_per_job: float = 0.0) -> float:
        b = self.pkmeans_bytes(n, d, k, iters)
        per_job = (b["read"] + b["write"]) / max(iters, 1)
        return iters * (self.job_sec(n, per_job) + compute_sec_per_job)

    def ipkmeans_sec(self, n: int, d: int, k: int, m: int, kd_depth: int,
                     reducer_sec: float = 0.0) -> float:
        b = self.ipkmeans_bytes(n, d, k, m, kd_depth)
        jobs = b["jobs"]
        per_job = (b["read"] + b["write"]) / max(jobs, 1)
        return jobs * self.job_sec(n, per_job) + reducer_sec


def tpu_collective_bytes_pkmeans(d: int, k: int, iters: int,
                                 n_devices: int, dtype_bytes: int = 4):
    """TPU-native restatement of Fig 5: ICI bytes PKMeans moves per solve.
    Ring all-reduce of (K*d sums + K counts + 1 shift) floats, 2x traffic
    factor (reduce-scatter + all-gather), once per Lloyd iteration."""
    payload = (k * d + k + 1) * dtype_bytes
    return iters * 2 * payload * (n_devices - 1)


def tpu_collective_bytes_ipkmeans(n: int, d: int, k: int, m: int,
                                  kd_depth: int, n_devices: int,
                                  dtype_bytes: int = 4):
    """IPKMeans ICI bytes: S1's sorts move O(n) per level (all_to_all-ish,
    counted pessimistically as one full dataset pass per level), S2 moves
    ZERO bytes (the whole point), S3 gathers M*K centroids once."""
    pass_bytes = n * d * dtype_bytes
    s1 = kd_depth * pass_bytes + pass_bytes          # tree levels + packing
    s3 = m * k * d * dtype_bytes
    return s1 + s3


# ---------------- cross-pod (DCN) reduction pricing ----------------
# On the (pods x devices) mesh, S2 keeps zero collectives on the fast axis
# but gains exactly one per-iteration (sums, counts) reduction over the
# slow DCN axis — the dominant pod-scale cost this model prices.

def ipkmeans_stats_payload_bytes(m: int, k: int, d: int,
                                 mode: str = "exact") -> int:
    """Bytes ONE pod contributes per Lloyd iteration to the cross-pod
    (sums, counts) reduction of ``m`` subsets — the quantity
    ``distributed/compress.payload_bytes`` measures on the actual payload
    trees, restated analytically.  ``"exact"`` ships f32 stats;
    ``"int8ef"`` ships int8 values plus their f32 scales (per sums row /
    per counts vector).  The int8ef/exact ratio is
    ``(k*d + 5k + 4) / (4k*(d+1))`` — under 1/3 for d >= 16, the paper's
    2/3-lower-I/O headline restated at the pod scale."""
    if mode == "exact":
        return m * 4 * (k * d + k)            # f32 sums + f32 counts
    if mode == "int8ef":
        # int8 sums + f32 per-row scales; int8 counts + one f32 scale
        return m * ((k * d + 4 * k) + (k + 4))
    raise ValueError(f"unknown reduce mode: {mode!r} "
                     f"(expected 'exact' | 'int8ef')")


def dcn_reduce_bytes_ipkmeans(m: int, k: int, d: int, iters: int,
                              n_pods: int, mode: str = "exact") -> int:
    """DCN bytes one pod exchanges over a whole cross-pod S2 solve.

    Priced as a ring all-reduce (reduce-scatter + all-gather: the familiar
    ``2 * payload * (p-1)/p`` per participant) for both modes so the modes
    differ only by payload — the apples-to-apples comparison kernel_bench
    prints.  (The current JAX lowering expresses the int8 reduction as an
    all-gather + local dequant-sum, because int8 summation is only defined
    after dequantization; that trades the 2x ring factor for a (p-1)
    gather factor — a wash at the 2-4 pod scale this repo exercises.)
    ``iters`` is the max Lloyd iteration count across subsets: lanes that
    converge early still ride the fused reduction until the last lane
    stops, exactly like the while-loop they run in."""
    if n_pods <= 1:
        return 0
    payload = ipkmeans_stats_payload_bytes(m, k, d, mode)
    return iters * 2 * payload * (n_pods - 1) // n_pods


def s1_histogram_dcn_bytes(depth: int, n_pods: int, dtype_bytes: int = 4,
                           rounds: int = 8, buckets: int = 256) -> int:
    """DCN bytes one pod exchanges for the SHARDED S1 (build + label).

    Build: at tree level ``l`` there are ``2**l`` regions, and the exact
    median selection runs ``rounds`` radix rounds (4 key bytes + 4
    tie-break index bytes), each psum-ing a (regions, buckets) int32
    histogram plus one per-region count vector — ring-priced like the S2
    stats reduction.  Label: one more (R, buckets) histogram at the leaf
    level plus the per-region lo/hi span, then ``ceil(log2 p)``
    Hillis-Steele exchange rounds of the (R * buckets) local histogram for
    the cross-shard exclusive scan.  The total is independent of n — the
    whole point: the sort-based S1 moves the dataset per level
    (:func:`s1_sort_dcn_bytes`), the histogram S1 moves only summaries.
    """
    if n_pods <= 1:
        return 0

    def ring(payload: int) -> int:
        return 2 * payload * (n_pods - 1) // n_pods

    total = 0
    for level in range(depth):
        regions = 2 ** level
        total += rounds * ring(regions * buckets * dtype_bytes)
        total += ring(regions * dtype_bytes)            # per-region counts
    r = 2 ** depth
    total += ring(r * buckets * dtype_bytes)            # label histogram
    total += ring(2 * r * dtype_bytes)                  # per-region lo/hi
    total += (max(n_pods - 1, 1)).bit_length() * r * buckets * dtype_bytes
    return total


def s1_sort_dcn_bytes(n: int, d: int, depth: int,
                      dtype_bytes: int = 4) -> int:
    """DCN bytes of the replicated sort-based S1 when points live sharded
    over pods: every level's global lexsort (and the final labeling sort)
    is a dataset-sized exchange — the floor GSPMD's all-gather lowering
    cannot beat.  This is the baseline :func:`s1_histogram_dcn_bytes`
    replaces."""
    return (depth + 1) * n * d * dtype_bytes
