"""Centroid initialization strategies.

The paper evaluates with fixed, shared initial centroids (same centroids fed
to PKMeans and to every IPKMeans reducer) — ``sample_init`` reproduces that.
Beyond the paper, seeding is exactly what controls iterations-to-converge,
which for the resident/batched megakernels means on-chip while-loop trips
per launch:

  * ``kmeans_plus_plus`` — classic sequential k-means++ (Arthur &
    Vassilvitskii 2007), k passes; robust to degenerate residual mass
    (duplicated points, ``k`` > distinct points) by masking chosen indices
    out of the distribution and falling back to uniform over the remainder.
    Selection only: every centroid IS an input point.  Also the weighted
    recluster core of the k-means|| driver.
  * ``kmeans_parallel_init`` — k-means|| (Scalable K-Means++, Bahmani et
    al., PAPERS.md): O(log n) *rounds*, each ONE fused distance+min+sample
    sweep over the points (``kernels/init.py``; ``backend="ref"`` runs the
    bitwise-identical jnp oracle), oversampling an expected ``ell``
    candidates per round, then a weighted k-means++ recluster of the
    ~``ell * rounds`` candidates on-host.  With a ``mesh``, each round's
    sweep runs per-shard under ``shard_map`` (points sharded, candidates
    replicated, potential psum'd) — the distributed path.
  * ``resolve_init`` — the strategy dispatcher the pipeline entry points
    (``kmeans``, ``ipkmeans``, ``ipkmeans_distributed``) call when
    ``init != "given"``.  Runs on host (rounds are a host loop over kernel
    launches), which is why init resolution lives at the entry points and
    not inside the jitted solver cores.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import metrics
from repro.kernels import ref

#: strategies understood by the pipeline (``KMeansParams.init`` /
#: ``IPKMeansConfig.with_init``).  "given" = caller supplies centroids.
INIT_METHODS = ("given", "sample", "kmeans++", "kmeans||")


@partial(jax.jit, static_argnames=("k",))
def sample_init(points: jnp.ndarray, key: jax.Array, k: int) -> jnp.ndarray:
    """Sample k distinct points uniformly as initial centroids.

    Top-k of i.i.d. uniform keys: the k largest draws are a uniform
    k-subset, with O(n) work and O(k) selection state — no O(n)
    permutation materialized (``random.choice(..., replace=False)``
    permutes the whole index range).
    """
    r = jax.random.uniform(key, (points.shape[0],))
    _, idx = jax.lax.top_k(r, k)
    return points[idx]


@partial(jax.jit, static_argnames=("k",))
def kmeans_plus_plus(points: jnp.ndarray, key: jax.Array, k: int,
                     weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """k-means++ seeding (Arthur & Vassilvitskii 2007): each next centroid is
    sampled proportionally to (weighted) squared distance from the chosen set.

    Degeneracy-robust: already-chosen indices are masked out of every draw,
    and when the residual D^2 mass underflows to ~0 (duplicated points,
    ``k`` greater than the number of distinct points) the draw falls back to
    uniform over the not-yet-chosen remainder — so the returned centroids
    are k distinct input points whenever ``k <= n``.  ``weights`` (optional,
    (n,)) scale each point's mass — zero-weight points are drawn only by the
    last-resort fallback; this weighted form is the k-means|| recluster.
    """
    n, d = points.shape
    w0 = (jnp.ones((n,), jnp.float32) if weights is None
          else weights.astype(jnp.float32))

    def draw(sub, mass, chosen):
        # mass over unchosen -> weighted remainder -> uniform remainder ->
        # uniform over everything (k > n; only then may repeats appear)
        residual = jnp.where(chosen, 0.0, mass)
        weighted = jnp.where(chosen, 0.0, w0)
        uniform = jnp.where(chosen, 0.0, 1.0)
        src = jnp.where(jnp.sum(residual) > 0.0, residual,
                        jnp.where(jnp.sum(weighted) > 0.0, weighted,
                                  jnp.where(jnp.sum(uniform) > 0.0, uniform,
                                            jnp.ones((n,), jnp.float32))))
        probs = src / jnp.maximum(jnp.sum(src), 1e-30)
        return jax.random.choice(sub, n, p=probs)

    k0, key = jax.random.split(key)
    first = draw(k0, w0, jnp.zeros((n,), bool))
    chosen = jnp.zeros((n,), bool).at[first].set(True)
    centroids = jnp.zeros((k, d), points.dtype).at[0].set(points[first])

    def body(i, carry):
        cents, chosen, key = carry
        key, sub = jax.random.split(key)
        d2 = metrics.pairwise_sq_dists(points, cents)
        # distances to not-yet-chosen slots must not win the min
        valid = jnp.arange(k) < i
        d2 = jnp.where(valid[None, :], d2, jnp.inf)
        mass = jnp.min(d2, axis=-1) * w0
        idx = draw(sub, mass, chosen)
        return (cents.at[i].set(points[idx]), chosen.at[idx].set(True), key)

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids, chosen, key))
    return centroids


# ------------------------------------------------------------- k-means|| ---

@partial(jax.jit, static_argnames=("ell",))
def _ref_sweep(points, cands, cand_valid, old_mind, uniforms, weights,
               psi_prev, *, ell):
    return ref.init_sweep_ref(points, cands, old_mind, uniforms, psi_prev,
                              ell=ell, cand_valid=cand_valid, weights=weights)


def _make_sweep(backend: str, spec, mesh, axis_names):
    """The per-round sweep callable: fused Pallas kernel or jnp oracle,
    optionally wrapped in a per-shard ``shard_map`` round (points/mind/
    uniforms/weights sharded over ``axis_names``, candidates replicated,
    partial potentials psum'd — on a 1-device mesh this is bitwise the
    single-host sweep)."""
    if backend == "kernel":
        from repro.kernels import ops

        def sweep(x, cands, valid, om, u, w, pp, ell):
            return ops.init_sweep(x, cands, om, u, pp, ell=ell,
                                  cand_valid=valid, weights=w, spec=spec)
    elif backend == "ref":
        def sweep(x, cands, valid, om, u, w, pp, ell):
            return _ref_sweep(x, cands, valid, om, u, w, pp, ell=ell)
    else:
        raise ValueError(f"unknown init sweep backend: {backend!r} "
                         f"(expected 'kernel' | 'ref')")
    if mesh is None:
        return sweep

    def sharded(x, cands, valid, om, u, w, pp, ell):
        def body(xs, oms, us, ws):
            mind, samp, psi = sweep(xs, cands, valid, oms, us, ws, pp, ell)
            return mind, samp, jax.lax.psum(psi, axis_names)

        sp = P(axis_names)
        run = shard_map(body, mesh=mesh, in_specs=(sp, sp, sp, sp),
                        out_specs=(sp, sp, P()), check_vma=False)
        return run(x, om, u, w)

    return sharded


def kmeans_parallel_init(points: jnp.ndarray, key: jax.Array, k: int, *,
                         ell: float | None = None,
                         rounds: int | None = None,
                         weights: jnp.ndarray | None = None,
                         backend: str = "kernel",
                         spec=None,
                         mesh=None,
                         axis_names: tuple[str, ...] = ("data",),
                         return_stats: bool = False):
    """k-means|| seeding (Bahmani et al.): oversampled O(log n)-round init.

    Round structure — each round is ONE fused sweep (kernel or oracle) that
    (a) folds the previous round's new candidates into the running per-point
    min squared distance, (b) reduces the new potential ``psi = sum(w *
    mind)``, and (c) Bernoulli-draws the round's candidates with probability
    ``min(1, ell * mind / psi_prev)``.  Sampling uses the PREVIOUS round's
    potential — the slightly conservative variant that makes one sweep per
    round possible (the potential is non-increasing, so draw probabilities
    are only ever under-, never over-estimated).  Round 0 scores the
    weighted-uniform first pick with ``psi_prev = 0`` (no draws).  The
    ~``ell * rounds`` candidates are then weighted by how many points each
    one captures (one assignment pass) and reclustered with weighted
    k-means++ *selection* — so every returned centroid is an input point.

    Defaults: ``ell = 2k`` (the paper's recommended O(k) oversampling),
    ``rounds = min(8, max(2, ceil(log2(n / k))))`` — the O(log n) round
    count, capped because ~5 rounds suffice in practice (Bahmani §5).

    ``backend="kernel"`` runs the fused Pallas sweep (``kernels/init.py``),
    ``"ref"`` the bitwise-identical jnp oracle.  ``spec`` pins the kernel
    geometry (default: the autotuned init winner for the steady-state
    candidate tile, else module defaults).  With ``mesh``, each sweep runs
    per-shard under ``shard_map`` with the candidate set replicated.
    """
    points = jnp.asarray(points)
    n, d = points.shape
    if n < 1:
        raise ValueError("kmeans_parallel_init needs at least one point")
    ell = float(2 * k) if ell is None else float(ell)
    if rounds is None:
        rounds = min(8, max(2, int(math.ceil(math.log2(max(n, 2) / max(k, 1))
                                             )) if n > k else 2))
    rounds = max(1, int(rounds))
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else jnp.asarray(weights, jnp.float32))

    if spec is None and backend == "kernel":
        from repro.kernels import tuning
        cap0 = max(8, 1 << max(0, int(math.ceil(ell)) - 1).bit_length())
        spec = tuning.lookup_init_spec(n, d, cap0, points.dtype)
    sweep = _make_sweep(backend, spec, mesh, axis_names)

    keys = jax.random.split(key, rounds + 3)
    first_key, recluster_key, round_keys = keys[0], keys[1], keys[2:]
    # weighted-uniform first pick (uniform when unweighted)
    probs = np.asarray(w, np.float64)
    total = probs.sum()
    probs = (probs / total if total > 0
             else np.full((n,), 1.0 / n))
    first = int(jax.random.choice(first_key, n,
                                  p=jnp.asarray(probs, jnp.float32)))

    cand_idx = [first]
    new_idx = np.array([first], np.int64)
    old_mind = jnp.full((n,), jnp.inf, jnp.float32)
    psi_prev = jnp.float32(0.0)
    psi_trace = []
    # sweeps 0..rounds: sweep r folds round r-1's draws and draws round r's
    # (round 0 folds the first pick and draws nothing: psi_prev == 0); the
    # final sweep's draws join the pool unfolded — the recluster weighting
    # re-scores every candidate anyway.  A candidate folds to mind == 0, so
    # the strict Bernoulli inequality can never re-draw it: the pool is
    # duplicate-free by construction.
    for r in range(rounds + 1):
        u = jax.random.uniform(round_keys[r], (n,), jnp.float32)
        # pad the new-candidate buffer to a power of two so the round loop
        # compiles O(log) kernel variants, not one per candidate count
        cap = max(8, 1 << max(0, int(new_idx.size) - 1).bit_length())
        idx_pad = np.zeros((cap,), np.int64)
        idx_pad[:new_idx.size] = new_idx
        cands = points[jnp.asarray(idx_pad)]
        valid = jnp.asarray(np.arange(cap) < new_idx.size)
        mind, samp, psi = sweep(points, cands, valid, old_mind, u, w,
                                psi_prev, ell)
        old_mind, psi_prev = mind, psi
        psi_trace.append(float(psi))
        new_idx = np.flatnonzero(np.asarray(samp))
        cand_idx.extend(new_idx.tolist())

    cand = np.unique(np.asarray(cand_idx, np.int64))
    if cand.size < k:
        # degenerate draw (tiny n, tiny ell): top up with the farthest
        # points so the recluster always has k distinct rows when n >= k
        order = np.argsort(-np.asarray(old_mind), kind="stable")
        have = set(cand.tolist())
        extra = [i for i in order if int(i) not in have][:k - cand.size]
        cand = np.concatenate([cand, np.asarray(extra, np.int64)])

    cands = points[jnp.asarray(cand)]
    # candidate weights: total point mass each candidate captures
    if backend == "kernel":
        from repro.kernels import ops
        labels, _ = ops.assign(points, cands, spec=spec)
    else:
        labels, _ = ref.assign_ref(points, cands)
    cweights = jnp.zeros((cand.size,), jnp.float32).at[labels].add(w)
    centroids = kmeans_plus_plus(cands, recluster_key, k, weights=cweights)
    centroids = centroids.astype(points.dtype)
    if return_stats:
        return centroids, {"candidates": int(cand.size), "rounds": rounds,
                           "ell": ell, "psi": psi_trace}
    return centroids


# ------------------------------------------------------------- dispatch ----

def resolve_init(points: jnp.ndarray, key: jax.Array, k: int, method: str, *,
                 weights: jnp.ndarray | None = None,
                 backend: str = "kernel",
                 spec=None,
                 mesh=None,
                 axis_names: tuple[str, ...] = ("data",)) -> jnp.ndarray:
    """Resolve an init strategy name to (k, d) centroids.

    The single entry the pipeline wrappers call; ``method="given"`` is the
    callers' own branch (they already hold centroids).  ``backend`` selects
    the k-means|| sweep implementation (``"kernel"`` | ``"ref"``); the
    host-loop strategies ignore it.
    """
    if method not in INIT_METHODS or method == "given":
        raise ValueError(f"unknown init method: {method!r} "
                         f"(expected one of {INIT_METHODS[1:]})")
    if method == "sample":
        return sample_init(points, key, k)
    if method == "kmeans++":
        return kmeans_plus_plus(points, key, k, weights=weights)
    return kmeans_parallel_init(points, key, k, weights=weights,
                                backend=backend, spec=spec,
                                mesh=mesh, axis_names=axis_names)
