"""Centroid initialization strategies.

The paper evaluates with fixed, shared initial centroids (same centroids fed
to PKMeans and to every IPKMeans reducer) — ``sample_init`` reproduces that.
``kmeans_plus_plus`` is provided as a beyond-paper option.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import metrics


@partial(jax.jit, static_argnames=("k",))
def sample_init(points: jnp.ndarray, key: jax.Array, k: int) -> jnp.ndarray:
    """Sample k distinct points uniformly as initial centroids."""
    idx = jax.random.choice(key, points.shape[0], (k,), replace=False)
    return points[idx]


@partial(jax.jit, static_argnames=("k",))
def kmeans_plus_plus(points: jnp.ndarray, key: jax.Array, k: int) -> jnp.ndarray:
    """k-means++ seeding (Arthur & Vassilvitskii 2007): each next centroid is
    sampled proportionally to squared distance from the chosen set."""
    n, d = points.shape
    k0, key = jax.random.split(key)
    first = points[jax.random.randint(k0, (), 0, n)]
    centroids = jnp.zeros((k, d), points.dtype).at[0].set(first)

    def body(i, carry):
        cents, key = carry
        key, sub = jax.random.split(key)
        d2 = metrics.pairwise_sq_dists(points, cents)
        # distances to not-yet-chosen slots must not win the min
        valid = jnp.arange(k) < i
        d2 = jnp.where(valid[None, :], d2, jnp.inf)
        w = jnp.min(d2, axis=-1)
        probs = w / jnp.maximum(jnp.sum(w), 1e-30)
        idx = jax.random.choice(sub, n, p=probs)
        return cents.at[i].set(points[idx]), key

    centroids, _ = jax.lax.fori_loop(1, k, body, (centroids, key))
    return centroids
