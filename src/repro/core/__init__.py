"""Core library: IPKMeans (the paper's contribution) + PKMeans baseline."""
from repro.core.ipkmeans import (IPKMeansConfig, IPKMeansResult, ipkmeans,
                                 ipkmeans_distributed)
from repro.core.kmeans import (KMeansParams, KMeansResult, kmeans,
                               kmeans_batched, update_minibatch)
from repro.core.pkmeans import PKMeansResult, pkmeans, pkmeans_sharded
from repro.core import init, io_model, kdtree, merge, metrics, serve

__all__ = [
    "IPKMeansConfig", "IPKMeansResult", "ipkmeans", "ipkmeans_distributed",
    "KMeansParams", "KMeansResult", "kmeans", "kmeans_batched",
    "update_minibatch",
    "PKMeansResult", "pkmeans", "pkmeans_sharded",
    "init", "io_model", "kdtree", "merge", "metrics", "serve",
]
