"""Level-synchronous k-d tree partitioning + subset labeling (paper Algs 2-3).

The paper builds the tree with one MapReduce job per level: reducers split
each sub-region at the exact median along a cycling axis, appending one bit to
the region id.  The TPU adaptation keeps the *level-synchronous* schedule but
replaces the shuffle with a lexicographic sort: one (region, coord) sort per
level computes every region's exact median split simultaneously.  ``depth``
levels <=> the paper's O(log n) MapReduce jobs.

Everything is pure jnp and jit-safe for a static ``depth`` / ``num_subsets``,
and — because sorts and scatters are SPMD-partitionable — runs sharded under
pjit on a mesh without modification.  Past one pod that is no longer enough:
GSPMD lowers the level sorts and the scatter pack as dataset-sized
collectives over the slow DCN axis.  The ``*_sharded`` variants here run the
same algorithms under ``shard_map`` with points sharded over
``(pods, devices)`` and exchange only O(regions * 256) histogram summaries
per radix round — the whole S1 then scales past single-pod memory with
per-level cross-host traffic independent of n (see
:func:`build_kdtree_histogram_sharded`, :func:`label_regions_histogram_sharded`,
and the ``pod_axis`` mode of :func:`pack_subsets_a2a`).
"""
from __future__ import annotations

import math
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map


class Partition(NamedTuple):
    subset_ids: jnp.ndarray      # (n,) int32 in [0, num_subsets)
    region_ids: jnp.ndarray      # (n,) int32 in [0, 2**depth) — tree leaves
    depth: int                   # tree levels == number of "MapReduce jobs"


def _segment_rank(sort_primary: jnp.ndarray, order: jnp.ndarray, num_segments: int):
    """Given a permutation ``order`` that sorts by (segment, key), return for
    each *sorted* position its rank within its segment and the segment size."""
    n = sort_primary.shape[0]
    sorted_seg = sort_primary[order]
    counts = jnp.bincount(sort_primary, length=num_segments)           # (m,)
    starts = jnp.cumsum(counts) - counts                               # (m,)
    rank = jnp.arange(n, dtype=jnp.int32) - starts[sorted_seg].astype(jnp.int32)
    size = counts[sorted_seg].astype(jnp.int32)
    return sorted_seg, rank, size


@partial(jax.jit, static_argnames=("depth",))
def build_kdtree(points: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Assign every point a leaf region id via ``depth`` median-split rounds.

    Axes cycle x, y, x, y, ... exactly as in the paper's 2-D construction;
    the left child takes ceil(size/2) points ("split at median point").
    Returns (n,) int32 region ids in [0, 2**depth).
    """
    n, d = points.shape
    region = jnp.zeros(n, dtype=jnp.int32)
    for level in range(depth):
        axis = level % d
        coord = points[:, axis]
        order = jnp.lexsort((coord, region))           # sort by region, then coord
        sorted_seg, rank, size = _segment_rank(region, order, 2 ** level)
        child = (rank >= (size + 1) // 2).astype(jnp.int32)
        new_sorted = sorted_seg * 2 + child
        region = jnp.zeros_like(region).at[order].set(new_sorted)
    return region


def _monotone_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Order-preserving float32 -> uint32 mapping (IEEE-754 trick)."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jnp.where((b >> 31) == 1, ~b, b | jnp.uint32(0x80000000))


def _histogram_median_go_right(key: jnp.ndarray, idx: jnp.ndarray,
                               region: jnp.ndarray, num_regions: int,
                               axis_names: tuple[str, ...] | None = None,
                               active: jnp.ndarray | None = None):
    """Exact per-region median split WITHOUT sorting.

    Radix-refines the median over 8 byte-rounds (4 bytes of the monotone
    float key + 4 bytes of the point index as a unique tie-break, matching
    the stable lexsort's ordering).  Per round: one histogram scatter-add
    of active points into (R, 256) bins — O(n) traffic and an O(R*256)
    reduction, vs a full O(n log n) global sort per tree level.  This is
    the §Perf cell-C optimization; equality with the sort-based splitter
    is asserted in tests.

    With ``axis_names`` the function runs inside ``shard_map`` over a
    points-sharded mesh: all per-point state stays shard-local and each
    (R, 256) histogram (plus the initial per-region counts) is psum'd over
    the mesh axes, so the cross-shard traffic per round is O(R * 256) ints
    regardless of n.  Because histogram entries are integer adds, the
    reduced counts — and therefore every median decision — are bit-for-bit
    identical to the single-device build as long as ``idx`` carries
    globally-unique point indices.  ``active`` masks shard-padding rows out
    of every count (their ``less``/``match`` outputs are meaningless).
    """
    n = key.shape[0]
    live = jnp.ones(n, bool) if active is None else active
    counts = jnp.zeros((num_regions,), jnp.int32).at[region].add(
        live.astype(jnp.int32))
    if axis_names is not None:
        counts = jax.lax.psum(counts, axis_names)
    remaining = ((counts + 1) // 2).astype(jnp.int32)     # ceil -> left
    match = live
    less = jnp.zeros(n, bool)
    for r in range(8):
        if r < 4:
            byte = (key >> (8 * (3 - r))) & jnp.uint32(0xFF)
        else:
            byte = (idx >> (8 * (7 - r))) & jnp.uint32(0xFF)
        byte = byte.astype(jnp.int32)
        hist = jnp.zeros((num_regions * 256,), jnp.int32).at[
            region * 256 + byte].add(match.astype(jnp.int32))
        hist = hist.reshape(num_regions, 256)
        if axis_names is not None:
            hist = jax.lax.psum(hist, axis_names)
        cum = jnp.cumsum(hist, axis=1)
        bstar = jnp.argmax(cum >= remaining[:, None], axis=1).astype(jnp.int32)
        below = jnp.where(bstar > 0,
                          jnp.take_along_axis(
                              cum, jnp.maximum(bstar - 1, 0)[:, None],
                              axis=1)[:, 0],
                          0)
        remaining = remaining - below.astype(jnp.int32)
        b_reg = bstar[region]
        less = less | (match & (byte < b_reg))
        match = match & (byte == b_reg)
    # the unique surviving point is the median element; it joins the left
    # half iff one left-slot remains (remaining == 1 by construction)
    left = less | (match & (remaining[region] > 0))
    return ~left


@partial(jax.jit, static_argnames=("depth",))
def build_kdtree_histogram(points: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Sort-free k-d tree build: identical output to :func:`build_kdtree`
    (exact medians, same tie-breaks) via radix-histogram median selection.
    O(depth * 8) histogram passes instead of O(depth) global sorts."""
    n, d = points.shape
    idx = jnp.arange(n, dtype=jnp.uint32)
    keys = [_monotone_u32(points[:, a]) for a in range(d)]
    region = jnp.zeros(n, dtype=jnp.int32)
    for level in range(depth):
        key = keys[level % d]
        go_right = _histogram_median_go_right(key, idx, region, 2 ** level)
        region = region * 2 + go_right.astype(jnp.int32)
    return region


def _shard_linear_index(mesh, axis_names: tuple[str, ...]):
    """Linearized (major-to-minor over ``axis_names``) program index inside a
    shard_map body — matches how ``P(axis_names)`` tiles a global array, so
    ``linear_index * n_loc`` is the shard's global row offset."""
    idx = jnp.int32(0)
    for a in axis_names:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _mesh_size(mesh, axis_names: tuple[str, ...]) -> int:
    size = 1
    for a in axis_names:
        size *= mesh.shape[a]
    return size


def _pad_for_shards(arrs, n: int, n_shards: int):
    """Pad leading axis to a multiple of ``n_shards``; returns the padded
    arrays plus the (n_pad,) active mask (all-True when already even)."""
    pad = -n % n_shards
    if pad:
        arrs = [jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]) for a in arrs]
    active = jnp.arange(n + pad) < n
    return arrs, active


def build_kdtree_histogram_sharded(points: jnp.ndarray, depth: int,
                                   mesh, axis_names: tuple[str, ...]
                                   ) -> jnp.ndarray:
    """Multi-host k-d tree build: :func:`build_kdtree_histogram` under
    ``shard_map`` with points sharded over ``axis_names`` (on the k-means pod
    mesh: ``("pods", "data")`` — the slow DCN axis plus the in-pod devices).

    Each shard radix-refines every region's median on its local points and
    psums the (R, 256) byte histograms across the mesh per round, so the
    cross-host traffic per tree level is depth-independent O(R * 256) ints —
    the dataset itself never moves.  Point indices are globally unique
    (shard offset + local arange), preserving the stable-sort tie-break:
    region ids are bit-for-bit identical to the single-device build
    (asserted in tests, ties and all).  n that doesn't divide the shard
    count is padded internally with masked rows.
    """
    n, d = points.shape
    axes = tuple(axis_names)
    n_shards = _mesh_size(mesh, axes)
    (pts,), active = _pad_for_shards([points], n, n_shards)
    n_loc = pts.shape[0] // n_shards

    def body(x_loc, act_loc):
        offset = (_shard_linear_index(mesh, axes) * n_loc).astype(jnp.uint32)
        idx = offset + jnp.arange(n_loc, dtype=jnp.uint32)
        keys = [_monotone_u32(x_loc[:, a]) for a in range(d)]
        region = jnp.zeros(n_loc, dtype=jnp.int32)
        for level in range(depth):
            go_right = _histogram_median_go_right(
                keys[level % d], idx, region, 2 ** level,
                axis_names=axes, active=act_loc)
            region = region * 2 + go_right.astype(jnp.int32)
        return region

    from jax.sharding import PartitionSpec as P
    region = shard_map(body, mesh=mesh,
                       in_specs=(P(axes, None), P(axes)),
                       out_specs=P(axes), check_vma=False)(pts, active)
    return region[:n]


def required_depth(n: int, leaf_capacity: int) -> int:
    """Levels so leaves hold ~leaf_capacity points.

    The paper splits 'until every sub region contains at most M points' and
    its Table-3 arithmetic (58 reducers x 258-point subsets on 15000 pts)
    implies leaves of size *closest to* M — one split further would halve
    the leaves and leave subsets M/2..M-1 empty (labels are ranks within
    the leaf).  So: depth = round(log2(n / capacity)), leaf in (M/2, M]."""
    if n <= leaf_capacity:
        return 0
    return max(0, round(math.log2(n / leaf_capacity)))


def _label_key(points: jnp.ndarray, key: jax.Array, strategy: str,
               label_axis: int) -> jnp.ndarray:
    """The per-point labeling key for Algorithm 3's two variants."""
    if strategy == "axis":
        return points[:, label_axis]
    if strategy == "random":
        return jax.random.uniform(key, (points.shape[0],))
    raise ValueError(f"unknown labeling strategy: {strategy}")


@partial(jax.jit, static_argnames=("num_regions", "num_subsets", "strategy", "label_axis"))
def label_regions(points: jnp.ndarray,
                  region_ids: jnp.ndarray,
                  key: jax.Array,
                  num_regions: int,
                  num_subsets: int,
                  strategy: str = "axis",
                  label_axis: int = 0) -> jnp.ndarray:
    """Paper Algorithm 3: label points 1..M inside each leaf; label i forms
    subset i.  ``strategy``:

      * ``'axis'``   — variant (2): sort along ``label_axis`` inside the leaf
        and label left-to-right (the paper's winning variant).
      * ``'random'`` — variant (1): random permutation inside the leaf.

    Labels wrap mod ``num_subsets`` so leaf capacity need not equal M.
    """
    key2 = _label_key(points, key, strategy, label_axis)
    order = jnp.lexsort((key2, region_ids))
    _, rank, _ = _segment_rank(region_ids, order, num_regions)
    label_sorted = (rank % num_subsets).astype(jnp.int32)
    return jnp.zeros_like(region_ids).at[order].set(label_sorted)


# Number of histogram buckets per region for the sort-free labeler.  256
# matches the radix fan-out of the tree build; with leaf_capacity-sized
# regions each bucket holds only a handful of points, so the bucketed order
# is as stratified as the exact sort for the paper's labeling purpose.
_LABEL_BUCKETS = 256


def _region_buckets(key2: jnp.ndarray, region_ids: jnp.ndarray,
                    num_regions: int, active: jnp.ndarray | None = None,
                    axis_names: tuple[str, ...] | None = None) -> jnp.ndarray:
    """Per-point bucket id in [0, 256): the point's labeling key quantized
    against its region's [min, max] span.  Scatter-min/max per region, pmin /
    pmax across shards when ``axis_names`` is given — min/max are order-
    independent, so sharded and single-device buckets are bit-identical."""
    f = key2.astype(jnp.float32)
    reg = region_ids if active is None else jnp.where(
        active, region_ids, num_regions)
    lo = jnp.full((num_regions,), jnp.inf, f.dtype).at[reg].min(f, mode="drop")
    hi = jnp.full((num_regions,), -jnp.inf, f.dtype).at[reg].max(f, mode="drop")
    if axis_names:
        lo = jax.lax.pmin(lo, axis_names)
        hi = jax.lax.pmax(hi, axis_names)
    w = hi - lo
    t = (f - lo[region_ids]) / jnp.where(w > 0, w, 1.0)[region_ids]
    return jnp.clip((t * _LABEL_BUCKETS).astype(jnp.int32),
                    0, _LABEL_BUCKETS - 1)


@partial(jax.jit, static_argnames=("num_regions", "num_subsets", "strategy", "label_axis"))
def label_regions_histogram(points: jnp.ndarray,
                            region_ids: jnp.ndarray,
                            key: jax.Array,
                            num_regions: int,
                            num_subsets: int,
                            strategy: str = "axis",
                            label_axis: int = 0) -> jnp.ndarray:
    """Single-device reference for the sort-free labeling order.

    Canonical order inside a region: (bucket, original index), where bucket
    quantizes the labeling key against the region's span
    (:func:`_region_buckets`).  This is the order the distributed labeler
    (:func:`label_regions_histogram_sharded`) reproduces bit-for-bit from
    O(R * 256) summaries — the exact-key order of :func:`label_regions`
    cannot be recovered without a dataset-sized exchange, so the histogram
    pair defines its own (equally stratified) canonical order instead.
    """
    key2 = _label_key(points, key, strategy, label_axis)
    b = _region_buckets(key2, region_ids, num_regions)
    order = jnp.lexsort((b, region_ids))
    _, rank, _ = _segment_rank(region_ids, order, num_regions)
    label_sorted = (rank % num_subsets).astype(jnp.int32)
    return jnp.zeros_like(region_ids).at[order].set(label_sorted)


def _exclusive_shard_scan(x: jnp.ndarray, mesh,
                          axis_names: tuple[str, ...]) -> jnp.ndarray:
    """Sum of ``x`` over all shards with a strictly smaller linearized
    (major-to-minor over ``axis_names``) index.

    Hillis-Steele doubling over ``lax.ppermute`` per axis — ceil(log2 P)
    rounds of O(|x|) messages, instead of the all-gather of every shard's
    copy (which at production shapes is a multi-GB blow-up)."""
    axes = tuple(axis_names)
    out = jnp.zeros_like(x)
    for pos, a in enumerate(axes):
        inner = axes[pos + 1:]
        t = jax.lax.psum(x, inner) if inner else x
        inc = t
        shift = 1
        while shift < mesh.shape[a]:
            perm = [(s, s + shift) for s in range(mesh.shape[a] - shift)]
            inc = inc + jax.lax.ppermute(inc, a, perm)
            shift *= 2
        out = out + (inc - t)
    return out


def label_regions_histogram_sharded(points: jnp.ndarray,
                                    region_ids: jnp.ndarray,
                                    num_regions: int,
                                    num_subsets: int,
                                    mesh,
                                    axis_names: tuple[str, ...],
                                    label_axis: int = 0) -> jnp.ndarray:
    """Distributed Algorithm 3 (axis variant) without the global lexsort.

    A point's rank inside its region decomposes into three order-independent
    pieces: (a) the count of region points in strictly smaller buckets — an
    exclusive cumsum of the psum'd (R, 256) histogram; (b) the count of
    same-(region, bucket) points on shards with smaller linear index — an
    exclusive shard scan of the local histogram; (c) its stable local rank
    within the (region, bucket) cell.  Because global point order is
    shard-major, (a)+(b)+(c) equals the single-device
    :func:`label_regions_histogram` rank exactly, so subset ids are
    bit-for-bit identical — while cross-shard traffic is O(R * 256) ints
    instead of the dataset-sized all-gather GSPMD makes of a lexsort.
    """
    n = points.shape[0]
    axes = tuple(axis_names)
    n_shards = _mesh_size(mesh, axes)
    (pts, reg), active = _pad_for_shards([points, region_ids], n, n_shards)
    nb = num_regions * _LABEL_BUCKETS

    def body(x_loc, reg_loc, act_loc):
        b = _region_buckets(x_loc[:, label_axis], reg_loc, num_regions,
                            active=act_loc, axis_names=axes)
        rb = jnp.where(act_loc, reg_loc * _LABEL_BUCKETS + b, nb)
        hist_loc = jnp.zeros(nb, jnp.int32).at[rb].add(
            act_loc.astype(jnp.int32), mode="drop")
        hist = jax.lax.psum(hist_loc, axes)
        h2 = hist.reshape(num_regions, _LABEL_BUCKETS)
        base = (jnp.cumsum(h2, axis=1) - h2).reshape(-1)   # (a): bucket start
        pref = _exclusive_shard_scan(hist_loc, mesh, axes)  # (b): shard prefix
        order = jnp.argsort(rb, stable=True)                # (c): local rank
        _, lrank_sorted, _ = _segment_rank(rb, order, nb + 1)
        lrank = jnp.zeros_like(lrank_sorted).at[order].set(lrank_sorted)
        rbc = jnp.minimum(rb, nb - 1)  # padded rows: any in-range cell
        rank = base[rbc] + pref[rbc] + lrank
        return (rank % num_subsets).astype(jnp.int32)

    from jax.sharding import PartitionSpec as P
    label = shard_map(body, mesh=mesh,
                      in_specs=(P(axes, None), P(axes), P(axes)),
                      out_specs=P(axes), check_vma=False)(pts, reg, active)
    return label[:n]


@partial(jax.jit, static_argnames=("num_subsets",))
def random_partition(points: jnp.ndarray, key: jax.Array, num_subsets: int):
    """Variant (3): global random partition, no k-d tree (ablation baseline).

    Uses a random permutation + round-robin so subset sizes stay balanced,
    matching how HashPartitioner would spread records across reducers."""
    n = points.shape[0]
    perm = jax.random.permutation(key, n)
    ids = jnp.zeros(n, dtype=jnp.int32).at[perm].set(
        (jnp.arange(n) % num_subsets).astype(jnp.int32))
    return ids


@partial(jax.jit, static_argnames=("num_subsets", "capacity"))
def pack_subsets(points: jnp.ndarray,
                 subset_ids: jnp.ndarray,
                 num_subsets: int,
                 capacity: int):
    """Scatter points into a rectangular (M, capacity, d) tensor + bool mask.

    This is the shuffle that routes each subset to its reducer.  Points beyond
    ``capacity`` in a subset are dropped (cannot happen for kd-tree labeling
    with capacity >= ceil(num_leaves * leaf_cap / M); asserted in tests).
    """
    n, d = points.shape
    order = jnp.argsort(subset_ids, stable=True)
    sorted_sub, rank, _ = _segment_rank(subset_ids, order, num_subsets)
    out = jnp.zeros((num_subsets, capacity, d), points.dtype)
    msk = jnp.zeros((num_subsets, capacity), bool)
    # ranks >= capacity fall out of bounds and are dropped by mode='drop'
    out = out.at[sorted_sub, rank].set(points[order], mode="drop")
    msk = msk.at[sorted_sub, rank].set(True, mode="drop")
    return out, msk


@partial(jax.jit, static_argnames=("num_subsets", "capacity"))
def pack_subsets_sorted(points: jnp.ndarray,
                        subset_ids: jnp.ndarray,
                        num_subsets: int,
                        capacity: int):
    """Equal-size pack via one sort + reshape (no scatter).

    Valid when every subset holds exactly ``capacity`` points (true for the
    kd-tree labeling whenever n == num_subsets * capacity, i.e. full
    leaves).  GSPMD lowers the scatter in :func:`pack_subsets` as a
    local-scatter + full-output ALL-REDUCE (a dataset-sized reduction);
    the sort+gather formulation moves the data once instead — §Perf C2.
    """
    n, d = points.shape
    assert n == num_subsets * capacity, (n, num_subsets, capacity)
    order = jnp.argsort(subset_ids, stable=True)
    packed = points[order].reshape(num_subsets, capacity, d)
    return packed, jnp.ones((num_subsets, capacity), bool)


def pack_subsets_a2a(points: jnp.ndarray,
                     subset_ids: jnp.ndarray,
                     num_subsets: int,
                     capacity: int,
                     mesh,
                     axis_names: tuple[str, ...],
                     slack: float = 1.3,
                     pod_axis: str | None = None):
    """Communication-optimal pack: explicit all_to_all shuffle (§Perf C3).

    GSPMD lowers both the scatter- and the sort-based packs as dataset-
    sized all-reduce/all-gather; but the shuffle's destinations are known
    (subset s lives on device s // (M/R)), so a capacity-padded shard_map
    all_to_all moves each point exactly once — the same dispatch pattern as
    the MoE layer.  Per-(src,dst) capacity is n_loc/R * slack; overflow
    drops are impossible for region-aligned inputs and negligible for
    random order (the returned ``dropped`` count makes any loss loud).

    With ``pod_axis`` (the slow DCN axis of a pods x devices mesh, points
    sharded over ``(pod_axis,) + axis_names``) the all_to_all runs only
    over the in-pod ``axis_names``: a point moves to its subset's owner
    *column* inside its own pod row, and the packed tensor's capacity axis
    is sharded over pods (each pod owns a ``capacity // n_pods`` slice of
    every subset).  The pack itself therefore costs ZERO DCN payload —
    exactly the property the S2 cross-pod solve expects, since it reduces
    per-subset stats over the pod axis anyway.

    Returns ``(packed (M, capacity, d), mask (M, capacity), dropped)`` —
    packed/mask sharded (M over ``axis_names``, capacity over ``pod_axis``),
    ``dropped`` a replicated scalar count of points lost to slot or
    capacity overflow (0 in healthy configurations; callers should check).

    Preconditions (else: warn + scatter fallback): ``num_subsets`` divides
    by the in-pod device count, ``n`` by the total device count, and
    ``capacity`` by the pod count.
    """
    from jax.sharding import PartitionSpec as P

    n, d = points.shape
    r = _mesh_size(mesh, tuple(axis_names))
    n_pods = mesh.shape[pod_axis] if pod_axis else 1
    n_dev = r * n_pods
    precondition = None
    if num_subsets % r:
        precondition = f"num_subsets={num_subsets} % in-pod devices={r} != 0"
    elif n % n_dev:
        precondition = f"n={n} % devices={n_dev} != 0"
    elif capacity % n_pods:
        precondition = f"capacity={capacity} % pods={n_pods} != 0"
    if precondition:
        warnings.warn(
            "pack_subsets_a2a: falling back to the scatter pack "
            f"(all-reduce-shaped collective) because {precondition}",
            RuntimeWarning, stacklevel=2)
        out, msk = pack_subsets(points, subset_ids, num_subsets, capacity)
        return out, msk, jnp.int32(n) - msk.sum(dtype=jnp.int32)
    m_loc = num_subsets // r
    n_loc = n // n_dev
    cap_loc = capacity // n_pods
    # per-(src, dst) send slots: mean * slack plus a 4-sigma binomial floor —
    # at small per-destination means the multiplicative slack alone is tighter
    # than ordinary statistical fluctuation (send buffers are r*c_send*d
    # floats, so the extra headroom is noise)
    mean = n_loc / r
    c_send = max(8, -(-int(mean * slack + 4 * math.sqrt(mean)) // 8) * 8)
    axes = tuple(axis_names)
    all_axes = ((pod_axis,) + axes) if pod_axis else axes

    def body(pts_loc, ids_loc):
        # route local points to the in-pod device owning their subset
        dst = (ids_loc // m_loc).astype(jnp.int32)
        order = jnp.argsort(dst, stable=True)
        _, slot_sorted, _ = _segment_rank(dst, order, r)
        slot = jnp.zeros(n_loc, jnp.int32).at[order].set(slot_sorted)
        slot = jnp.where(slot < c_send, slot, c_send)        # drop overflow
        send_x = jnp.zeros((r, c_send, d), pts_loc.dtype).at[
            dst, slot].set(pts_loc, mode="drop")
        send_id = jnp.full((r, c_send), -1, jnp.int32).at[
            dst, slot].set(ids_loc.astype(jnp.int32), mode="drop")
        recv_x = jax.lax.all_to_all(send_x, axes, 0, 0, tiled=True)
        recv_id = jax.lax.all_to_all(send_id, axes, 0, 0, tiled=True)
        # local re-pack into (m_loc, cap_loc, d)
        flat_x = recv_x.reshape(r * c_send, d)
        flat_id = recv_id.reshape(r * c_send)
        local_sub = jnp.where(flat_id >= 0, flat_id % m_loc, m_loc)
        order2 = jnp.argsort(local_sub, stable=True)
        _, rank_sorted, _ = _segment_rank(local_sub, order2, m_loc + 1)
        rank = jnp.zeros(r * c_send, jnp.int32).at[order2].set(rank_sorted)
        valid = (flat_id >= 0) & (rank < cap_loc)
        out = jnp.zeros((m_loc, cap_loc, d), pts_loc.dtype).at[
            jnp.where(valid, local_sub, m_loc),
            jnp.where(valid, rank, cap_loc)].set(flat_x, mode="drop")
        msk = jnp.zeros((m_loc, cap_loc), bool).at[
            jnp.where(valid, local_sub, m_loc),
            jnp.where(valid, rank, cap_loc)].set(True, mode="drop")
        total = jax.lax.psum(msk.sum(dtype=jnp.int32), all_axes)
        return out, msk, total

    spec = P(all_axes)
    out, msk, total = shard_map(
        body, mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(P(axes, pod_axis, None), P(axes, pod_axis), P()),
        check_vma=False)(points, subset_ids)
    return out, msk, jnp.int32(n) - total


def partition_dataset(points: jnp.ndarray,
                      key: jax.Array,
                      num_subsets: int,
                      leaf_capacity: int | None = None,
                      strategy: str = "kd_axis",
                      label_axis: int = 0,
                      builder: str = "sort",
                      labeler: str = "sort",
                      mesh=None,
                      axis_names: tuple[str, ...] | None = None) -> Partition:
    """Full stage-1 pipeline: tree build + labeling (or random partition).

    ``strategy`` in {'kd_axis', 'kd_random', 'random'} — the paper's variants
    (2), (1) and (3) respectively.  ``builder``: 'sort' (paper-faithful
    level-sync sorts) or 'histogram' (identical output, sort-free — §Perf).
    ``labeler``: 'sort' (exact-key order) or 'histogram' (bucketed order,
    required for the distributed path).

    With ``mesh`` + ``axis_names`` the whole stage runs under ``shard_map``
    with points sharded over ``axis_names`` (e.g. ``("pods", "data")`` on the
    k-means pod mesh): per-level cross-shard traffic is the O(R * 256)
    histogram summaries, never the points.  Requires
    ``builder == labeler == 'histogram'`` and ``strategy == 'kd_axis'`` —
    the sort build/labeling would be lowered as dataset-sized collectives,
    and the random variants have no shard-invariant key stream.
    """
    n = points.shape[0]
    cap = num_subsets if leaf_capacity is None else leaf_capacity
    if strategy == "random":
        ids = random_partition(points, key, num_subsets)
        return Partition(subset_ids=ids,
                         region_ids=jnp.zeros(n, jnp.int32), depth=0)
    depth = required_depth(n, cap)
    if mesh is not None:
        if axis_names is None:
            raise ValueError("sharded partition_dataset needs axis_names")
        if builder != "histogram" or labeler != "histogram":
            raise ValueError(
                "sharded partition_dataset requires builder='histogram' and "
                f"labeler='histogram' (got {builder!r}/{labeler!r}); the "
                "sort paths lower as dataset-sized collectives")
        if strategy != "kd_axis":
            raise ValueError(
                f"sharded partition_dataset supports strategy='kd_axis' "
                f"only (got {strategy!r})")
        region = build_kdtree_histogram_sharded(points, depth, mesh,
                                                tuple(axis_names))
        ids = label_regions_histogram_sharded(points, region, 2 ** depth,
                                              num_subsets, mesh,
                                              tuple(axis_names),
                                              label_axis=label_axis)
        return Partition(subset_ids=ids, region_ids=region, depth=depth)
    build = build_kdtree_histogram if builder == "histogram" else build_kdtree
    region = build(points, depth)
    label_strategy = "axis" if strategy == "kd_axis" else "random"
    label = label_regions_histogram if labeler == "histogram" else label_regions
    ids = label(points, region, key, 2 ** depth, num_subsets,
                strategy=label_strategy, label_axis=label_axis)
    return Partition(subset_ids=ids, region_ids=region, depth=depth)
