"""Level-synchronous k-d tree partitioning + subset labeling (paper Algs 2-3).

The paper builds the tree with one MapReduce job per level: reducers split
each sub-region at the exact median along a cycling axis, appending one bit to
the region id.  The TPU adaptation keeps the *level-synchronous* schedule but
replaces the shuffle with a lexicographic sort: one (region, coord) sort per
level computes every region's exact median split simultaneously.  ``depth``
levels <=> the paper's O(log n) MapReduce jobs.

Everything is pure jnp and jit-safe for a static ``depth`` / ``num_subsets``,
and — because sorts and scatters are SPMD-partitionable — runs sharded under
pjit on a mesh without modification.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map


class Partition(NamedTuple):
    subset_ids: jnp.ndarray      # (n,) int32 in [0, num_subsets)
    region_ids: jnp.ndarray      # (n,) int32 in [0, 2**depth) — tree leaves
    depth: int                   # tree levels == number of "MapReduce jobs"


def _segment_rank(sort_primary: jnp.ndarray, order: jnp.ndarray, num_segments: int):
    """Given a permutation ``order`` that sorts by (segment, key), return for
    each *sorted* position its rank within its segment and the segment size."""
    n = sort_primary.shape[0]
    sorted_seg = sort_primary[order]
    counts = jnp.bincount(sort_primary, length=num_segments)           # (m,)
    starts = jnp.cumsum(counts) - counts                               # (m,)
    rank = jnp.arange(n, dtype=jnp.int32) - starts[sorted_seg].astype(jnp.int32)
    size = counts[sorted_seg].astype(jnp.int32)
    return sorted_seg, rank, size


@partial(jax.jit, static_argnames=("depth",))
def build_kdtree(points: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Assign every point a leaf region id via ``depth`` median-split rounds.

    Axes cycle x, y, x, y, ... exactly as in the paper's 2-D construction;
    the left child takes ceil(size/2) points ("split at median point").
    Returns (n,) int32 region ids in [0, 2**depth).
    """
    n, d = points.shape
    region = jnp.zeros(n, dtype=jnp.int32)
    for level in range(depth):
        axis = level % d
        coord = points[:, axis]
        order = jnp.lexsort((coord, region))           # sort by region, then coord
        sorted_seg, rank, size = _segment_rank(region, order, 2 ** level)
        child = (rank >= (size + 1) // 2).astype(jnp.int32)
        new_sorted = sorted_seg * 2 + child
        region = jnp.zeros_like(region).at[order].set(new_sorted)
    return region


def _monotone_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Order-preserving float32 -> uint32 mapping (IEEE-754 trick)."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jnp.where((b >> 31) == 1, ~b, b | jnp.uint32(0x80000000))


def _histogram_median_go_right(key: jnp.ndarray, idx: jnp.ndarray,
                               region: jnp.ndarray, num_regions: int):
    """Exact per-region median split WITHOUT sorting.

    Radix-refines the median over 8 byte-rounds (4 bytes of the monotone
    float key + 4 bytes of the point index as a unique tie-break, matching
    the stable lexsort's ordering).  Per round: one histogram scatter-add
    of active points into (R, 256) bins — O(n) traffic and an O(R*256)
    reduction, vs a full O(n log n) global sort per tree level.  This is
    the §Perf cell-C optimization; equality with the sort-based splitter
    is asserted in tests.
    """
    n = key.shape[0]
    counts = jnp.bincount(region, length=num_regions)
    remaining = ((counts + 1) // 2).astype(jnp.int32)     # ceil -> left
    match = jnp.ones(n, bool)
    less = jnp.zeros(n, bool)
    for r in range(8):
        if r < 4:
            byte = (key >> (8 * (3 - r))) & jnp.uint32(0xFF)
        else:
            byte = (idx >> (8 * (7 - r))) & jnp.uint32(0xFF)
        byte = byte.astype(jnp.int32)
        hist = jnp.zeros((num_regions * 256,), jnp.int32).at[
            region * 256 + byte].add(match.astype(jnp.int32))
        hist = hist.reshape(num_regions, 256)
        cum = jnp.cumsum(hist, axis=1)
        bstar = jnp.argmax(cum >= remaining[:, None], axis=1).astype(jnp.int32)
        below = jnp.where(bstar > 0,
                          jnp.take_along_axis(
                              cum, jnp.maximum(bstar - 1, 0)[:, None],
                              axis=1)[:, 0],
                          0)
        remaining = remaining - below.astype(jnp.int32)
        b_reg = bstar[region]
        less = less | (match & (byte < b_reg))
        match = match & (byte == b_reg)
    # the unique surviving point is the median element; it joins the left
    # half iff one left-slot remains (remaining == 1 by construction)
    left = less | (match & (remaining[region] > 0))
    return ~left


@partial(jax.jit, static_argnames=("depth",))
def build_kdtree_histogram(points: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Sort-free k-d tree build: identical output to :func:`build_kdtree`
    (exact medians, same tie-breaks) via radix-histogram median selection.
    O(depth * 8) histogram passes instead of O(depth) global sorts."""
    n, d = points.shape
    idx = jnp.arange(n, dtype=jnp.uint32)
    keys = [_monotone_u32(points[:, a]) for a in range(d)]
    region = jnp.zeros(n, dtype=jnp.int32)
    for level in range(depth):
        key = keys[level % d]
        go_right = _histogram_median_go_right(key, idx, region, 2 ** level)
        region = region * 2 + go_right.astype(jnp.int32)
    return region


def required_depth(n: int, leaf_capacity: int) -> int:
    """Levels so leaves hold ~leaf_capacity points.

    The paper splits 'until every sub region contains at most M points' and
    its Table-3 arithmetic (58 reducers x 258-point subsets on 15000 pts)
    implies leaves of size *closest to* M — one split further would halve
    the leaves and leave subsets M/2..M-1 empty (labels are ranks within
    the leaf).  So: depth = round(log2(n / capacity)), leaf in (M/2, M]."""
    if n <= leaf_capacity:
        return 0
    return max(0, round(math.log2(n / leaf_capacity)))


@partial(jax.jit, static_argnames=("num_regions", "num_subsets", "strategy", "label_axis"))
def label_regions(points: jnp.ndarray,
                  region_ids: jnp.ndarray,
                  key: jax.Array,
                  num_regions: int,
                  num_subsets: int,
                  strategy: str = "axis",
                  label_axis: int = 0) -> jnp.ndarray:
    """Paper Algorithm 3: label points 1..M inside each leaf; label i forms
    subset i.  ``strategy``:

      * ``'axis'``   — variant (2): sort along ``label_axis`` inside the leaf
        and label left-to-right (the paper's winning variant).
      * ``'random'`` — variant (1): random permutation inside the leaf.

    Labels wrap mod ``num_subsets`` so leaf capacity need not equal M.
    """
    if strategy == "axis":
        key2 = points[:, label_axis]
    elif strategy == "random":
        key2 = jax.random.uniform(key, (points.shape[0],))
    else:
        raise ValueError(f"unknown labeling strategy: {strategy}")
    order = jnp.lexsort((key2, region_ids))
    _, rank, _ = _segment_rank(region_ids, order, num_regions)
    label_sorted = (rank % num_subsets).astype(jnp.int32)
    return jnp.zeros_like(region_ids).at[order].set(label_sorted)


@partial(jax.jit, static_argnames=("num_subsets",))
def random_partition(points: jnp.ndarray, key: jax.Array, num_subsets: int):
    """Variant (3): global random partition, no k-d tree (ablation baseline).

    Uses a random permutation + round-robin so subset sizes stay balanced,
    matching how HashPartitioner would spread records across reducers."""
    n = points.shape[0]
    perm = jax.random.permutation(key, n)
    ids = jnp.zeros(n, dtype=jnp.int32).at[perm].set(
        (jnp.arange(n) % num_subsets).astype(jnp.int32))
    return ids


@partial(jax.jit, static_argnames=("num_subsets", "capacity"))
def pack_subsets(points: jnp.ndarray,
                 subset_ids: jnp.ndarray,
                 num_subsets: int,
                 capacity: int):
    """Scatter points into a rectangular (M, capacity, d) tensor + bool mask.

    This is the shuffle that routes each subset to its reducer.  Points beyond
    ``capacity`` in a subset are dropped (cannot happen for kd-tree labeling
    with capacity >= ceil(num_leaves * leaf_cap / M); asserted in tests).
    """
    n, d = points.shape
    order = jnp.argsort(subset_ids, stable=True)
    sorted_sub, rank, _ = _segment_rank(subset_ids, order, num_subsets)
    out = jnp.zeros((num_subsets, capacity, d), points.dtype)
    msk = jnp.zeros((num_subsets, capacity), bool)
    # ranks >= capacity fall out of bounds and are dropped by mode='drop'
    out = out.at[sorted_sub, rank].set(points[order], mode="drop")
    msk = msk.at[sorted_sub, rank].set(True, mode="drop")
    return out, msk


@partial(jax.jit, static_argnames=("num_subsets", "capacity"))
def pack_subsets_sorted(points: jnp.ndarray,
                        subset_ids: jnp.ndarray,
                        num_subsets: int,
                        capacity: int):
    """Equal-size pack via one sort + reshape (no scatter).

    Valid when every subset holds exactly ``capacity`` points (true for the
    kd-tree labeling whenever n == num_subsets * capacity, i.e. full
    leaves).  GSPMD lowers the scatter in :func:`pack_subsets` as a
    local-scatter + full-output ALL-REDUCE (a dataset-sized reduction);
    the sort+gather formulation moves the data once instead — §Perf C2.
    """
    n, d = points.shape
    assert n == num_subsets * capacity, (n, num_subsets, capacity)
    order = jnp.argsort(subset_ids, stable=True)
    packed = points[order].reshape(num_subsets, capacity, d)
    return packed, jnp.ones((num_subsets, capacity), bool)


def pack_subsets_a2a(points: jnp.ndarray,
                     subset_ids: jnp.ndarray,
                     num_subsets: int,
                     capacity: int,
                     mesh,
                     axis_names: tuple[str, ...],
                     slack: float = 1.3):
    """Communication-optimal pack: explicit all_to_all shuffle (§Perf C3).

    GSPMD lowers both the scatter- and the sort-based packs as dataset-
    sized all-reduce/all-gather; but the shuffle's destinations are known
    (subset s lives on device s // (M/R)), so a capacity-padded shard_map
    all_to_all moves each point exactly once — the same dispatch pattern as
    the MoE layer.  Per-(src,dst) capacity is n_loc/R * slack; overflow
    drops are impossible for region-aligned inputs and negligible for
    random order (asserted via mask count in tests).

    Returns (packed (M, capacity, d) sharded over M, mask) — same contract
    as :func:`pack_subsets`.
    """
    from jax.sharding import PartitionSpec as P

    n, d = points.shape
    r = 1
    for a in axis_names:
        r *= mesh.shape[a]
    if num_subsets % r or n % r:
        return pack_subsets(points, subset_ids, num_subsets, capacity)
    m_loc = num_subsets // r
    n_loc = n // r
    c_send = max(8, -(-int(n_loc / r * slack) // 8) * 8)

    def body(pts_loc, ids_loc):
        # route local points to the device owning their subset
        dst = (ids_loc // m_loc).astype(jnp.int32)
        order = jnp.argsort(dst, stable=True)
        _, slot_sorted, _ = _segment_rank(dst, order, r)
        slot = jnp.zeros(n_loc, jnp.int32).at[order].set(slot_sorted)
        slot = jnp.where(slot < c_send, slot, c_send)        # drop overflow
        send_x = jnp.zeros((r, c_send, d), pts_loc.dtype).at[
            dst, slot].set(pts_loc, mode="drop")
        send_id = jnp.full((r, c_send), -1, jnp.int32).at[
            dst, slot].set(ids_loc.astype(jnp.int32), mode="drop")
        recv_x = jax.lax.all_to_all(send_x, axis_names, 0, 0, tiled=True)
        recv_id = jax.lax.all_to_all(send_id, axis_names, 0, 0, tiled=True)
        # local re-pack into (m_loc, capacity, d)
        flat_x = recv_x.reshape(r * c_send, d)
        flat_id = recv_id.reshape(r * c_send)
        local_sub = jnp.where(flat_id >= 0, flat_id % m_loc, m_loc)
        order2 = jnp.argsort(local_sub, stable=True)
        _, rank_sorted, _ = _segment_rank(local_sub, order2, m_loc + 1)
        rank = jnp.zeros(r * c_send, jnp.int32).at[order2].set(rank_sorted)
        valid = (flat_id >= 0) & (rank < capacity)
        out = jnp.zeros((m_loc, capacity, d), pts_loc.dtype).at[
            jnp.where(valid, local_sub, m_loc),
            jnp.where(valid, rank, capacity)].set(flat_x, mode="drop")
        msk = jnp.zeros((m_loc, capacity), bool).at[
            jnp.where(valid, local_sub, m_loc),
            jnp.where(valid, rank, capacity)].set(True, mode="drop")
        return out, msk

    spec = P(axis_names)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(P(axis_names, None, None), P(axis_names, None)),
        check_vma=False)(points, subset_ids)


def partition_dataset(points: jnp.ndarray,
                      key: jax.Array,
                      num_subsets: int,
                      leaf_capacity: int | None = None,
                      strategy: str = "kd_axis",
                      label_axis: int = 0,
                      builder: str = "sort") -> Partition:
    """Full stage-1 pipeline: tree build + labeling (or random partition).

    ``strategy`` in {'kd_axis', 'kd_random', 'random'} — the paper's variants
    (2), (1) and (3) respectively.  ``builder``: 'sort' (paper-faithful
    level-sync sorts) or 'histogram' (identical output, sort-free — §Perf).
    """
    n = points.shape[0]
    cap = num_subsets if leaf_capacity is None else leaf_capacity
    if strategy == "random":
        ids = random_partition(points, key, num_subsets)
        return Partition(subset_ids=ids,
                         region_ids=jnp.zeros(n, jnp.int32), depth=0)
    depth = required_depth(n, cap)
    build = build_kdtree_histogram if builder == "histogram" else build_kdtree
    region = build(points, depth)
    label_strategy = "axis" if strategy == "kd_axis" else "random"
    ids = label_regions(points, region, key, 2 ** depth, num_subsets,
                        strategy=label_strategy, label_axis=label_axis)
    return Partition(subset_ids=ids, region_ids=region, depth=depth)
