"""Stage-3 centroid merging (paper Section 2.iii).

Inputs are the K*M intermediate centroids from M per-subset k-means runs.
Both algorithms operate on a few-thousand-float tensor, so they run replicated
("single machine is enough" — paper) but are still jit-compiled and mask-based
so they compose with the end-to-end pipeline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("num_clusters",))
def hierarchical_merge(centroids: jnp.ndarray, num_clusters: int) -> jnp.ndarray:
    """Algorithm 5: repeatedly replace the closest active pair by its midpoint
    until only ``num_clusters`` remain, run as a fixed-trip ``fori_loop``
    over (N - K) merge steps with an active mask (N = K*M).

    The (N, N) distance matrix is computed ONCE and carried through the
    loop: a merge only moves centroid ``i`` (to the midpoint) and retires
    centroid ``j``, so each step rewrites just those two rows/columns —
    O(N*d + N^2) per step for the update+argmin instead of the O(N^2*d)
    full-matrix recompute (O(N^3*d) total) this loop used to pay.

    Returns (num_clusters, d): the surviving centroids, packed by sorting the
    active mask (inactive rows pushed to the end and sliced off).
    """
    n, d = centroids.shape
    steps = n - num_clusters
    if steps <= 0:
        return centroids[:num_clusters]

    idx = jnp.arange(n)
    d2_0 = jnp.sum((centroids[:, None, :] - centroids[None, :, :]) ** 2,
                   axis=-1)
    d2_0 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2_0)

    def body(_, carry):
        c, active, d2 = carry
        flat = jnp.argmin(d2)                  # inactive/self rows are +inf
        i, j = flat // n, flat % n
        mid = 0.5 * (c[i] + c[j])
        c = c.at[i].set(mid)
        active = active.at[j].set(False)
        # only row/col i (moved to mid) and row/col j (retired) changed
        di = jnp.sum((c - mid) ** 2, axis=-1)
        di = jnp.where(active & (idx != i), di, jnp.inf)
        d2 = d2.at[i, :].set(di).at[:, i].set(di)
        d2 = d2.at[j, :].set(jnp.inf).at[:, j].set(jnp.inf)
        return c, active, d2

    c, active, _ = jax.lax.fori_loop(
        0, steps, body, (centroids, jnp.ones(n, dtype=bool), d2_0))
    # pack the `num_clusters` active rows to the front (stable by index)
    order = jnp.argsort(~active, stable=True)
    return c[order][:num_clusters]


@jax.jit
def min_asse_merge(centroid_sets: jnp.ndarray, asses: jnp.ndarray) -> jnp.ndarray:
    """Paper's minimum-ASSE selection: among the M per-subset centroid sets
    (M, K, d), return the set whose subset had the lowest average SSE.
    O(M); "more robust and reliable than hierarchical merging" (Section 3.v).
    """
    best = jnp.argmin(asses)
    return centroid_sets[best]
