"""Stage-3 centroid merging (paper Section 2.iii).

Inputs are the K*M intermediate centroids from M per-subset k-means runs.
Both algorithms operate on a few-thousand-float tensor, so they run replicated
("single machine is enough" — paper) but are still jit-compiled and mask-based
so they compose with the end-to-end pipeline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("num_clusters",))
def hierarchical_merge(centroids: jnp.ndarray, num_clusters: int) -> jnp.ndarray:
    """Algorithm 5: repeatedly replace the closest active pair by its midpoint
    until only ``num_clusters`` remain.  O(N^3) with N = K*M, run as a
    fixed-trip ``fori_loop`` over (N - K) merge steps with an active mask.

    Returns (num_clusters, d): the surviving centroids, packed by sorting the
    active mask (inactive rows pushed to the end and sliced off).
    """
    n, d = centroids.shape
    steps = n - num_clusters
    if steps <= 0:
        return centroids[:num_clusters]

    def body(_, carry):
        c, active = carry
        d2 = jnp.sum((c[:, None, :] - c[None, :, :]) ** 2, axis=-1)
        pair_ok = active[:, None] & active[None, :]
        d2 = jnp.where(pair_ok, d2, jnp.inf)
        d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)
        flat = jnp.argmin(d2)
        i, j = flat // n, flat % n
        mid = 0.5 * (c[i] + c[j])
        c = c.at[i].set(mid)
        active = active.at[j].set(False)
        return c, active

    c, active = jax.lax.fori_loop(
        0, steps, body, (centroids, jnp.ones(n, dtype=bool)))
    # pack the `num_clusters` active rows to the front (stable by index)
    order = jnp.argsort(~active, stable=True)
    return c[order][:num_clusters]


@jax.jit
def min_asse_merge(centroid_sets: jnp.ndarray, asses: jnp.ndarray) -> jnp.ndarray:
    """Paper's minimum-ASSE selection: among the M per-subset centroid sets
    (M, K, d), return the set whose subset had the lowest average SSE.
    O(M); "more robust and reliable than hierarchical merging" (Section 3.v).
    """
    best = jnp.argmin(asses)
    return centroid_sets[best]
