"""Distance / SSE / ASSE metrics shared across the k-means stack.

All functions are pure jnp, jit- and vmap-safe, and accept an optional point
mask so padded points (used to make subset tensors rectangular) contribute
nothing to any statistic.
"""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists(points: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distances, (n, d) x (k, d) -> (n, k).

    Uses the ||x||^2 - 2 x.c + ||c||^2 decomposition so the inner product is a
    single matmul (MXU-friendly; this is also the contraction the Pallas
    assignment kernel implements).  Clamped at zero against cancellation.
    """
    x2 = jnp.sum(points * points, axis=-1, keepdims=True)          # (n, 1)
    c2 = jnp.sum(centroids * centroids, axis=-1)[None, :]          # (1, k)
    xc = points @ centroids.T                                      # (n, k)
    return jnp.maximum(x2 - 2.0 * xc + c2, 0.0)


def masked_count(mask: jnp.ndarray | None, n: int) -> jnp.ndarray:
    if mask is None:
        return jnp.asarray(n, jnp.float32)
    return jnp.sum(mask.astype(jnp.float32))


def sse(points: jnp.ndarray, centroids: jnp.ndarray,
        mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Sum of squared errors of each point to its nearest centroid."""
    d2 = pairwise_sq_dists(points, centroids)
    m = jnp.min(d2, axis=-1)
    if mask is not None:
        m = jnp.where(mask, m, 0.0)
    return jnp.sum(m)


def asse(points: jnp.ndarray, centroids: jnp.ndarray,
         mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Average SSE (the paper's merge-selection criterion, Section 2.iii.b)."""
    total = sse(points, centroids, mask)
    cnt = masked_count(mask, points.shape[0])
    return total / jnp.maximum(cnt, 1.0)


def centroid_shift(new: jnp.ndarray, old: jnp.ndarray) -> jnp.ndarray:
    """Max euclidean movement over centroids — the paper's stop criterion."""
    return jnp.max(jnp.sqrt(jnp.sum((new - old) ** 2, axis=-1)))
