"""Version shims for the JAX API surface this repo targets.

The codebase is written against the modern ``jax.shard_map`` entry point
(with its ``check_vma`` argument).  Older jaxlibs (<0.5) only ship
``jax.experimental.shard_map.shard_map`` whose equivalent knob is named
``check_rep``.  Everything routes through :func:`shard_map` here so the
solvers, the MoE layers, and the dry-run launchers run unmodified on both —
which is what lets the CI kernel/tier-1 jobs execute on whatever jax the
runner has.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                          # jax >= 0.5

    def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:                                                  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types where the installed
    jax knows about them (``jax.sharding.AxisType`` appeared after 0.4)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.set_mesh`` where it exists, else the legacy ``with mesh:`` form
    (Mesh is its own context manager on jax 0.4.x)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The ambient mesh (``jax.sharding.get_abstract_mesh`` on modern jax);
    on 0.4.x, the physical mesh installed by the legacy ``with mesh:`` form.
    Both expose ``.axis_names`` and a dict-like ``.shape``."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every jax: 0.4.x returns a
    one-element list of per-device dicts, newer jax returns the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
