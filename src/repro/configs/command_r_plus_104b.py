"""command-r-plus-104b [dense]: 64L d=12288 96H (GQA kv=8) d_ff=33792
vocab=256000.  Same Cohere family as command-r-35b (parallel block, tied
embeddings, no bias).  [hf:CohereForAI/c4ai-command-r-plus; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000, head_dim=128,
    norm="layernorm", parallel_block=True, tie_embeddings=True,
    logit_scale=0.0625, rope_theta=8_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="command-r-plus-104b-smoke", family="dense",
    num_layers=2, d_model=96, num_heads=12, num_kv_heads=2,
    d_ff=256, vocab_size=503, head_dim=8,
    norm="layernorm", parallel_block=True, tie_embeddings=True,
    logit_scale=0.0625, dtype="float32", remat="none",
)
