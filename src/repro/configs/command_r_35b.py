"""command-r-35b [dense]: 40L d=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.

Cohere Command R: parallel attention+FFN block off a single bias-free
LayerNorm, tied embeddings, logit scaling.  [hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000, head_dim=128,
    norm="layernorm", parallel_block=True, tie_embeddings=True,
    logit_scale=0.0625, rope_theta=8_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="command-r-35b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=160, vocab_size=503, head_dim=8,
    norm="layernorm", parallel_block=True, tie_embeddings=True,
    logit_scale=0.0625, rope_theta=8_000_000.0, dtype="float32",
    remat="none",
)
