"""Architecture registry: --arch <id> resolves here."""
from repro.configs import (chameleon_34b, command_r_35b, command_r_plus_104b,
                           deepseek_67b, deepseek_v3_671b, minicpm_2b,
                           mixtral_8x7b, recurrentgemma_9b,
                           seamless_m4t_large_v2, xlstm_125m)
from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                RecurrentConfig, XLSTMConfig)
from repro.configs.shapes import SHAPES, ShapeSpec, grid_cells, shape_applicable

_MODULES = {
    "command-r-35b": command_r_35b,
    "command-r-plus-104b": command_r_plus_104b,
    "deepseek-67b": deepseek_67b,
    "minicpm-2b": minicpm_2b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "mixtral-8x7b": mixtral_8x7b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "xlstm-125m": xlstm_125m,
    "chameleon-34b": chameleon_34b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
}

ARCHS = {name: m.CONFIG for name, m in _MODULES.items()}
SMOKE_ARCHS = {name: m.SMOKE_CONFIG for name, m in _MODULES.items()}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_ARCHS if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(table)}")
    return table[arch]


__all__ = ["ARCHS", "SMOKE_ARCHS", "get_config", "ModelConfig", "MoEConfig",
           "MLAConfig", "RecurrentConfig", "XLSTMConfig", "SHAPES",
           "ShapeSpec", "grid_cells", "shape_applicable"]
