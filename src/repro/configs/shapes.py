"""The four assigned input shapes and per-arch applicability.

  train_4k     seq 4096,   global batch 256   -> train_step
  prefill_32k  seq 32768,  global batch 32    -> prefill (serve)
  decode_32k   seq 32768,  global batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524288, global batch 1     -> serve_step; requires
                                                 sub-quadratic attention
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-not).  long_500k needs sub-quadratic attention
    (see DESIGN.md §Arch-applicability)."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 524k-token decode is "
                       "quadratic/O(S) KV — structurally skipped")
    return True, ""


def grid_cells(configs: dict[str, ModelConfig]):
    """All (arch, shape) cells with applicability flags."""
    cells = []
    for arch, cfg in configs.items():
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            cells.append({"arch": arch, "shape": shape,
                          "runnable": ok, "skip_reason": why})
    return cells
