"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8), 8 experts top-2
(expert d_ff=14336), vocab=32000, sliding-window attention (4096).

SWA makes the 500k-token decode cell runnable (ring KV cache of window
size).  Gather-based MoE dispatch: 8 experts, replicated over the model
axis with d_ff sharded.  [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    attention="sliding", window=4096,
    norm="rmsnorm", rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336,
                  router_score="softmax", capacity_factor=1.25,
                  dispatch="gather"),
    supports_long_context=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=160, vocab_size=503, head_dim=8,
    attention="sliding", window=32,
    norm="rmsnorm",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=48,
                  router_score="softmax", capacity_factor=8.0,
                  dispatch="gather"),
    supports_long_context=True, dtype="float32", remat="none",
)
