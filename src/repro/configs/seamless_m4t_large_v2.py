"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d=1024 16H (MHA kv=16) d_ff=8192 vocab=256206.

The speech frontend (w2v-BERT conformer feature extractor) is a STUB per
the assignment: input_specs() supplies precomputed (B, frames, d) frame
embeddings to the encoder; the decoder is a standard causal transformer
with cross-attention.  [arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, encoder_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    norm="layernorm", frontend="audio_frames",
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-m4t-large-v2-smoke", family="audio",
    num_layers=2, encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=503, head_dim=16,
    norm="layernorm", frontend="audio_frames",
    dtype="float32", remat="none",
)
