"""chameleon-34b [vlm]: 48L d=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.

Early-fusion multimodal: VQ-VAE image tokens share the text vocabulary, so
the backbone is a plain decoder over fused token streams (the VQ frontend
is the stub; IPKMeans trains the VQ codebook — examples/cluster_embeddings).
QK-norm per Chameleon's training-stability fix.  [arXiv:2405.09818]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, head_dim=128,
    norm="rmsnorm", qk_norm=True, rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="chameleon-34b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=160, vocab_size=503, head_dim=8,
    norm="rmsnorm", qk_norm=True, dtype="float32", remat="none",
)
