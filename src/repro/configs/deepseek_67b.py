"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.

Llama-architecture: RMSNorm, RoPE, SwiGLU, untied embeddings.
[arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400, head_dim=128,
    norm="rmsnorm", rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-67b-smoke", family="dense",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=160, vocab_size=503, head_dim=8,
    norm="rmsnorm", dtype="float32", remat="none",
)
