"""minicpm-2b [dense]: 40L d=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.

Llama-like with muP-style scaling: embeddings scaled by scale_emb=12,
residual branches by scale_depth/sqrt(L) = 1.4/sqrt(40), logits by
1/(d_model/dim_base) with dim_base=256; tied embeddings; trained with the
WSD schedule (implemented in repro.optim.schedules).  [arXiv:2404.06395]
"""
import math

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64,
    norm="rmsnorm", tie_embeddings=True,
    embed_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logit_scale=256.0 / 2304.0,
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm-2b-smoke", family="dense",
    num_layers=2, d_model=72, num_heads=6, num_kv_heads=6,
    d_ff=180, vocab_size=503, head_dim=12,
    norm="rmsnorm", tie_embeddings=True,
    embed_scale=12.0, residual_scale=1.4 / math.sqrt(2),
    logit_scale=256.0 / 2304.0, dtype="float32", remat="none",
)
