"""xlstm-125m [ssm]: 12L d=768 4H vocab=50304, d_ff=0 (FFN folded into the
mLSTM block's 2x up-projection).  xLSTM[10:2]-style mix: sLSTM blocks at
layers {3, 9}, mLSTM elsewhere.  Chunkwise-parallel mLSTM (chunk 256);
O(1) matrix-memory state => runs long_500k.  [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    norm="rmsnorm", tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_layers=(3, 9), num_heads=4,
                      proj_factor=2.0, chunk_size=256),
    supports_long_context=True,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-125m-smoke", family="ssm",
    num_layers=4, d_model=48, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab_size=503, head_dim=24,
    norm="rmsnorm", tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_layers=(1,), num_heads=2,
                      proj_factor=2.0, chunk_size=16),
    supports_long_context=True, dtype="float32", remat="none",
)
