"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (MQA kv=1) d_ff=12288
vocab=256000.  Griffin: repeating (RG-LRU, RG-LRU, local-attention-2048)
pattern — 12 full units + 2 trailing recurrent layers.  O(1) recurrent
state + ring local-attn cache => runs long_500k.  [arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    norm="rmsnorm",
    recurrent=RecurrentConfig(lru_width=4096, conv_width=4,
                              pattern=("rglru", "rglru", "attn"),
                              local_window=2048),
    supports_long_context=True,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    num_layers=4, d_model=48, num_heads=4, num_kv_heads=1,
    d_ff=96, vocab_size=503, head_dim=12,
    norm="rmsnorm",
    recurrent=RecurrentConfig(lru_width=48, conv_width=4,
                              pattern=("rglru", "rglru", "attn"),
                              local_window=16),
    supports_long_context=True, dtype="float32", remat="none",
)
