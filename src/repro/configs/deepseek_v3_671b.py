"""deepseek-v3-671b [moe]: 61L d=7168 128H, MoE 256 routed experts top-8 +
1 shared, expert d_ff=2048, vocab=129280, MLA attention.

First 3 layers are dense (d_ff=18432); layers 4-61 are MoE.  Router is
sigmoid-scored with normalized top-8 and routed_scaling=2.5.  MLA:
q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128.  MTP (multi-token
prediction) is a training objective, not an architecture change — noted as
out of scope in DESIGN.md.  [arXiv:2412.19437]
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432,                       # dense (first_dense_layers) FFN width
    vocab_size=129280,
    norm="rmsnorm", rope_theta=10_000.0,
    # gather (sort-based) dispatch: the GShard einsum one-hot is (T, E, C)
    # = O(1e13) elements at 1M tokens x 256 experts — the sort-based path
    # keeps dispatch state at O(T*top_k) indices + an (E, C, d) buffer.
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, d_ff_shared=2048,
                  router_score="sigmoid_norm", routed_scaling=2.5,
                  capacity_factor=1.25, dispatch="gather",
                  first_dense_layers=3),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=144, vocab_size=503,
    norm="rmsnorm",
    # capacity_factor 8 = drop-free at smoke scale, so teacher-forced and
    # incremental decode are bit-comparable in tests (capacity dropping is
    # load-dependent and legitimately differs between the two)
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=48,
                  num_shared_experts=1, d_ff_shared=48,
                  router_score="sigmoid_norm", routed_scaling=2.5,
                  capacity_factor=8.0, dispatch="einsum",
                  first_dense_layers=1),
    mla=MLAConfig(q_lora_rank=24, kv_lora_rank=16,
                  qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8),
    dtype="float32", remat="none",
)
