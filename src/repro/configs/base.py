"""Architecture config schema — one frozen dataclass drives every model.

Each assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact published shape) and ``SMOKE_CONFIG`` (same family, tiny dims) —
the smoke config runs real forward/train steps on CPU, the full config is
only ever lowered abstractly by the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int                    # per-expert hidden width
    num_shared_experts: int = 0         # DeepSeek-V3 shared expert(s)
    d_ff_shared: int = 0
    router_score: str = "softmax"       # 'softmax' | 'sigmoid_norm' (DSv3)
    capacity_factor: float = 1.25
    dispatch: str = "gather"            # 'dense' | 'gather' | 'einsum'
    first_dense_layers: int = 0         # leading layers use a dense FFN
    routed_scaling: float = 1.0         # DSv3 gate scaling


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """Griffin/RG-LRU (recurrentgemma) hybrid settings."""
    lru_width: int = 0                  # 0 -> d_model
    conv_width: int = 4
    pattern: tuple[str, ...] = ("rglru", "rglru", "attn")   # repeating unit
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_layers: tuple[int, ...] = ()  # indices using sLSTM blocks
    num_heads: int = 4
    proj_factor: float = 2.0            # mLSTM block up-projection
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 256               # mLSTM chunkwise-parallel chunk


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default d_model // num_heads
    # attention flavor
    attention: str = "full"             # 'full' | 'sliding'
    window: Optional[int] = None
    qk_norm: bool = False               # chameleon
    rope_theta: float = 10_000.0
    # block flavor
    norm: str = "rmsnorm"               # 'rmsnorm' | 'layernorm'
    parallel_block: bool = False        # command-r: attn + FFN in parallel
    tie_embeddings: bool = False
    logit_scale: float = 1.0            # command-r logit scaling
    embed_scale: float = 1.0            # minicpm scale_emb
    residual_scale: float = 1.0         # minicpm scale_depth / sqrt(L)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # enc-dec (seamless): encoder_layers > 0 => encoder-decoder model
    encoder_layers: int = 0
    # modality frontend stub: 'none' | 'audio_frames' (precomputed embeddings)
    frontend: str = "none"
    # numerics
    dtype: str = "bfloat16"
    # remat policy for the layer scan: 'none' | 'full' | 'dots'
    remat: str = "full"
    # chunked-attention sizes (perf-tunable; see EXPERIMENTS.md §Perf)
    q_chunk: int = 512
    kv_chunk: int = 1024
    # can this arch run the 500k-token decode shape?
    supports_long_context: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and the reports.  Mirrors the actual init shapes."""
        from repro.models.registry import count_params_abstract
        return count_params_abstract(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params_abstract
        return count_params_abstract(self, active_only=True)
