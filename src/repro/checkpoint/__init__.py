from repro.checkpoint import manager
from repro.checkpoint.manager import (AsyncCheckpointer, gc_old, latest_step,
                                      restore, restore_latest, save)

__all__ = ["manager", "AsyncCheckpointer", "save", "restore",
           "restore_latest", "latest_step", "gc_old"]
