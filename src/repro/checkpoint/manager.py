"""Checkpointing: atomic, async, mesh-elastic.

Layout: <dir>/step_<N>/ containing
  arrays.npz   — every leaf as a full logical array (key = flattened path)
  meta.json    — step, treedef repr, leaf manifest (shape/dtype), wall time
  COMMITTED    — sentinel written last; restore ignores uncommitted dirs

Design notes for 1000+ nodes (documented trade-offs):
  * Leaves are stored logically (unsharded), so a checkpoint written on one
    mesh restores onto ANY mesh — elastic re-sharding is a device_put with
    the new shardings (tests/test_checkpoint.py exercises 1->8 device moves
    and mesh reshape).  At real 671B scale arrays.npz becomes per-host shard
    files keyed by the same manifest; the commit protocol is unchanged.
  * AsyncCheckpointer snapshots to host (blocking only for device->host) and
    writes in a daemon thread — train-step overlap.
  * Atomicity: write into step_<N>.tmp, fsync, rename, then touch COMMITTED.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

from repro.models.common import Box


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(directory: str | os.PathLike, step: int, tree) -> Path:
    """Blocking atomic save of an arbitrary pytree of arrays."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    names, leaves, _ = _flatten_with_names(tree)
    host = [np.asarray(l) for l in leaves]
    # npz cannot round-trip ml_dtypes (bfloat16 etc.) — store a bit-exact
    # uint view and record the logical dtype in the manifest
    storable = [a.view(np.uint16) if a.dtype.name == "bfloat16" else a
                for a in host]
    np.savez(tmp / "arrays.npz",
             **{f"a{i}": a for i, a in enumerate(storable)})
    meta = {
        "step": step,
        "time": time.time(),
        "leaves": [{"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                   for n, a in zip(names, host)],
    }
    (tmp / "meta.json").write_text(json.dumps(meta, indent=2))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    (final / "COMMITTED").touch()
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if (p / "COMMITTED").exists()]
    return max(steps) if steps else None


def restore(directory: str | os.PathLike, step: int, like,
            shardings=None):
    """Restore into the structure of ``like`` (values or abstract values).
    ``shardings``: optional matching tree of NamedSharding for elastic
    re-sharding onto the current mesh."""
    path = Path(directory) / f"step_{step:08d}"
    if not (path / "COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    data = np.load(path / "arrays.npz")
    meta = json.loads((path / "meta.json").read_text())
    arrays = []
    for i, leaf_meta in enumerate(meta["leaves"]):
        a = data[f"a{i}"]
        if leaf_meta["dtype"] == "bfloat16":
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        arrays.append(a)
    names, leaves, treedef = _flatten_with_names(like)
    if len(arrays) != len(leaves):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, "
                         f"target tree has {len(leaves)}")
    for a, l, n in zip(arrays, leaves, names):
        if tuple(a.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch for {n}: "
                             f"{a.shape} vs {l.shape}")
    restored = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    else:
        restored = jax.tree.map(
            lambda a, l: jax.numpy.asarray(a, dtype=l.dtype),
            restored, jax.tree_util.tree_unflatten(treedef, leaves))
    return restored


def restore_latest(directory, like, shardings=None):
    step = latest_step(directory)
    if step is None:
        return None
    return step, restore(directory, step, like, shardings)


def gc_old(directory: str | os.PathLike, keep: int = 3):
    directory = Path(directory)
    steps = sorted(p for p in directory.glob("step_*")
                   if (p / "COMMITTED").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training: snapshot -> daemon write."""

    def __init__(self, directory, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, tree):
        self.wait()
        host = jax.tree.map(np.asarray, tree)    # device->host snapshot

        def work():
            save(self.directory, step, host)
            gc_old(self.directory, self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
