"""k-d tree partitioning invariants (paper Algorithms 2-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kdtree


@pytest.fixture(scope="module")
def pts():
    return jax.random.normal(jax.random.key(1), (1000, 2)) * 5


def test_median_split_balance(pts):
    region = kdtree.build_kdtree(pts, depth=4)
    counts = np.bincount(np.asarray(region), minlength=16)
    # exact median splits keep every leaf within +-1 of n/2^d at each level
    assert counts.min() >= 62 and counts.max() <= 63, counts


def test_leaves_are_spatial_boxes(pts):
    """Points in the same leaf after 2 levels share the x-median side and
    their region's y-median side (i.e., splits really are spatial)."""
    region = kdtree.build_kdtree(pts, depth=1)
    x = np.asarray(pts[:, 0])
    r = np.asarray(region)
    assert x[r == 0].max() <= x[r == 1].min() + 1e-6


def test_required_depth():
    assert kdtree.required_depth(3000, 6) == 9     # 3000/2^9 = 5.86 <= 6
    assert kdtree.required_depth(64, 8) == 3
    assert kdtree.required_depth(5, 6) == 0


@pytest.mark.parametrize("strategy", ["kd_axis", "kd_random", "random"])
def test_partition_is_exhaustive(pts, strategy):
    part = kdtree.partition_dataset(pts, jax.random.key(2), 8,
                                    strategy=strategy)
    ids = np.asarray(part.subset_ids)
    assert ids.min() >= 0 and ids.max() < 8
    counts = np.bincount(ids, minlength=8)
    assert counts.sum() == 1000
    # balanced to within one point per leaf
    assert counts.max() - counts.min() <= (2 ** part.depth if part.depth
                                           else 1)


def test_axis_labeling_is_stratified(pts):
    """Every leaf contributes at most ceil(leaf/M) points to each subset —
    the representativeness guarantee random partitioning lacks."""
    m = 8
    part = kdtree.partition_dataset(pts, jax.random.key(3), m)
    region = np.asarray(part.region_ids)
    ids = np.asarray(part.subset_ids)
    for r in np.unique(region):
        sel = ids[region == r]
        per = np.bincount(sel, minlength=m)
        assert per.max() <= -(-len(sel) // m)


def test_pack_subsets_roundtrip(pts):
    m = 8
    part = kdtree.partition_dataset(pts, jax.random.key(4), m)
    cap = 2 ** part.depth
    packed, mask = kdtree.pack_subsets(pts, part.subset_ids, m, cap)
    assert packed.shape == (m, cap, 2)
    # every original point appears exactly once among masked entries
    got = np.asarray(packed[np.asarray(mask)])
    orig = np.asarray(pts)
    got_sorted = got[np.lexsort(got.T)]
    orig_sorted = orig[np.lexsort(orig.T)]
    np.testing.assert_allclose(got_sorted, orig_sorted, rtol=1e-6)
    assert int(mask.sum()) == 1000
