"""k-d tree partitioning invariants (paper Algorithms 2-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kdtree


@pytest.fixture(scope="module")
def pts():
    return jax.random.normal(jax.random.key(1), (1000, 2)) * 5


def test_median_split_balance(pts):
    region = kdtree.build_kdtree(pts, depth=4)
    counts = np.bincount(np.asarray(region), minlength=16)
    # exact median splits keep every leaf within +-1 of n/2^d at each level
    assert counts.min() >= 62 and counts.max() <= 63, counts


def test_leaves_are_spatial_boxes(pts):
    """Points in the same leaf after 2 levels share the x-median side and
    their region's y-median side (i.e., splits really are spatial)."""
    region = kdtree.build_kdtree(pts, depth=1)
    x = np.asarray(pts[:, 0])
    r = np.asarray(region)
    assert x[r == 0].max() <= x[r == 1].min() + 1e-6


def test_required_depth():
    assert kdtree.required_depth(3000, 6) == 9     # 3000/2^9 = 5.86 <= 6
    assert kdtree.required_depth(64, 8) == 3
    assert kdtree.required_depth(5, 6) == 0


@pytest.mark.parametrize("strategy", ["kd_axis", "kd_random", "random"])
def test_partition_is_exhaustive(pts, strategy):
    part = kdtree.partition_dataset(pts, jax.random.key(2), 8,
                                    strategy=strategy)
    ids = np.asarray(part.subset_ids)
    assert ids.min() >= 0 and ids.max() < 8
    counts = np.bincount(ids, minlength=8)
    assert counts.sum() == 1000
    # balanced to within one point per leaf
    assert counts.max() - counts.min() <= (2 ** part.depth if part.depth
                                           else 1)


def test_axis_labeling_is_stratified(pts):
    """Every leaf contributes at most ceil(leaf/M) points to each subset —
    the representativeness guarantee random partitioning lacks."""
    m = 8
    part = kdtree.partition_dataset(pts, jax.random.key(3), m)
    region = np.asarray(part.region_ids)
    ids = np.asarray(part.subset_ids)
    for r in np.unique(region):
        sel = ids[region == r]
        per = np.bincount(sel, minlength=m)
        assert per.max() <= -(-len(sel) // m)


def test_pack_subsets_roundtrip(pts):
    m = 8
    part = kdtree.partition_dataset(pts, jax.random.key(4), m)
    cap = 2 ** part.depth
    packed, mask = kdtree.pack_subsets(pts, part.subset_ids, m, cap)
    assert packed.shape == (m, cap, 2)
    # every original point appears exactly once among masked entries
    got = np.asarray(packed[np.asarray(mask)])
    orig = np.asarray(pts)
    got_sorted = got[np.lexsort(got.T)]
    orig_sorted = orig[np.lexsort(orig.T)]
    np.testing.assert_allclose(got_sorted, orig_sorted, rtol=1e-6)
    assert int(mask.sum()) == 1000


def test_histogram_labeling_is_stratified(pts):
    """The bucketed-rank labeler keeps the paper's representativeness
    guarantee: every leaf contributes at most ceil(leaf/M) points per
    subset, exactly like the exact-sort labeler."""
    m = 8
    part = kdtree.partition_dataset(pts, jax.random.key(3), m,
                                    builder="histogram", labeler="histogram")
    region = np.asarray(part.region_ids)
    ids = np.asarray(part.subset_ids)
    assert np.bincount(ids, minlength=m).sum() == 1000
    for r in np.unique(region):
        sel = ids[region == r]
        per = np.bincount(sel, minlength=m)
        assert per.max() <= -(-len(sel) // m)


def test_histogram_labeler_matches_sort_on_distinct_buckets():
    """When every point in a region lands in its own bucket the bucketed
    order IS the key order, so the two labelers agree exactly.  linspace
    keys with < 256 points per region guarantee distinct buckets."""
    n, m, depth = 512, 4, 2
    x = jnp.linspace(0.0, 1.0, n)
    pts = jnp.stack([x, jnp.sin(x * 9.0)], axis=1)
    region = kdtree.build_kdtree_histogram(pts, depth)
    key = jax.random.key(0)
    a = kdtree.label_regions(pts, region, key, 2 ** depth, m)
    b = kdtree.label_regions_histogram(pts, region, key, 2 ** depth, m)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_partition_dataset_sharded_requires_histogram():
    pts = jax.random.normal(jax.random.key(0), (64, 2))

    class FakeMesh:
        shape = {"data": 1}
    with pytest.raises(ValueError, match="histogram"):
        kdtree.partition_dataset(pts, jax.random.key(1), 4,
                                 mesh=FakeMesh(), axis_names=("data",))
    with pytest.raises(ValueError, match="kd_axis"):
        kdtree.partition_dataset(pts, jax.random.key(1), 4,
                                 strategy="kd_random",
                                 builder="histogram", labeler="histogram",
                                 mesh=FakeMesh(), axis_names=("data",))
    with pytest.raises(ValueError, match="axis_names"):
        kdtree.partition_dataset(pts, jax.random.key(1), 4,
                                 builder="histogram", labeler="histogram",
                                 mesh=FakeMesh())


def test_pack_a2a_fallback_warns_and_counts():
    """The a2a preconditions failing must be LOUD: a RuntimeWarning naming
    the failed precondition, plus the 3-tuple contract with a dropped
    count (0 — the scatter fallback at adequate capacity loses nothing)."""
    n, m = 1000, 9                                  # n % devices != 0
    pts = jax.random.normal(jax.random.key(0), (n, 2))
    ids = (jnp.arange(n) % m).astype(jnp.int32)

    class FakeMesh:
        shape = {"data": 3}
    with pytest.warns(RuntimeWarning, match="n=1000"):
        packed, mask, dropped = kdtree.pack_subsets_a2a(
            pts, ids, m, 128, FakeMesh(), ("data",))
    assert int(dropped) == 0
    assert int(mask.sum()) == n
    with pytest.warns(RuntimeWarning, match="num_subsets=8"):
        kdtree.pack_subsets_a2a(pts, ids[:999] % 8, 8, 128,
                                FakeMesh(), ("data",))
