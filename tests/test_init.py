"""k-means|| fused init sweep + seeding strategies (ISSUE 7).

Covers: kernel-vs-oracle bitwise parity for the fused round sweep (shapes
that stress both padding regimes, dtypes, masks — all in interpret mode,
the CI kernel gate), the round-driver invariants (centroids are input
points, kernel == ref backend bitwise, non-increasing potential), the
sharded round on a 1-device mesh vs single-host, seed quality (blob SSE
property + a directed iterations-to-converge reduction), the robustness
satellites (``sample_init`` distinctness, ``kmeans_plus_plus`` degeneracy),
and the ``init=`` threading contract at the pipeline entry points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import metrics
from repro.core.init import (INIT_METHODS, kmeans_parallel_init,
                             kmeans_plus_plus, resolve_init, sample_init)
from repro.core.ipkmeans import IPKMeansConfig, ipkmeans, ipkmeans_distributed
from repro.core.kmeans import KMeansParams, kmeans, kmeans_batched
from repro.kernels import ops, ref, specs
from repro.kernels.engine import get_engine


def _sweep_inputs(n, d, c, seed=0, dtype=jnp.float32):
    kx, kc, ku, km = jax.random.split(jax.random.key(n * d + c + seed), 4)
    x = (3.0 * jax.random.normal(kx, (n, d))).astype(dtype)
    cd = (3.0 * jax.random.normal(kc, (c, d))).astype(dtype)
    u = jax.random.uniform(ku, (n,), jnp.float32)
    om = 50.0 * jax.random.uniform(km, (n,), jnp.float32)
    return x, cd, u, om


def _blobs(n, d, k, sep=12.0, noise=1.0, seed=0):
    kc, kn = jax.random.split(jax.random.key(seed))
    centers = sep * jax.random.normal(kc, (k, d), jnp.float32)
    x = centers[jnp.arange(n) % k] + noise * jax.random.normal(
        kn, (n, d), jnp.float32)
    return x


def _rows_in(points, centroids):
    """Every centroid row appears (bitwise) among the input rows."""
    pts = np.asarray(points)
    return all(np.any(np.all(pts == row, axis=1))
               for row in np.asarray(centroids))


# --------------------------------------------------- kernel vs oracle ------

# shapes stress both parity-critical pads: c < 8 (candidate axis padded to
# the sublane minimum), d > 128 (lane-boundary zero pad re-trees the dot),
# c > block_k (multi-tile candidate axis), and non-multiple n
SWEEP_SHAPES = [(64, 4, 8), (100, 7, 1), (257, 17, 5), (64, 130, 16),
                (128, 128, 8), (500, 3, 100)]


@pytest.mark.parametrize("n,d,c", SWEEP_SHAPES)
def test_init_sweep_matches_oracle_bitwise(n, d, c):
    """Fold + draw regime (finite old_mind, positive psi_prev): new_mind,
    sampled AND psi bitwise against the jnp oracle in grid order."""
    x, cd, u, om = _sweep_inputs(n, d, c)
    pp = jnp.float32(37.5)
    ell = float(2 * c)
    spec = specs.DEFAULT_SPEC
    mind_k, samp_k, psi_k = ops.init_sweep(
        x, cd, om, u, pp, ell=ell, spec=spec, interpret=True)
    bn = spec.tile_shapes(n, d, c)[0]
    mind_r, samp_r, psi_r = ref.init_sweep_ref(
        x, cd, om, u, pp, ell=ell, block_rows=bn)
    np.testing.assert_array_equal(np.asarray(mind_k), np.asarray(mind_r))
    np.testing.assert_array_equal(np.asarray(samp_k), np.asarray(samp_r))
    assert float(psi_k) == float(psi_r)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_init_sweep_dtypes(dtype):
    """bf16 points: products are exact in the f32 accumulator, so kernel
    and oracle stay bitwise."""
    x, cd, u, om = _sweep_inputs(96, 9, 6, dtype=dtype)
    pp = jnp.float32(21.0)
    mind_k, samp_k, psi_k = ops.init_sweep(
        x, cd, om, u, pp, ell=12.0, interpret=True)
    bn = specs.DEFAULT_SPEC.tile_shapes(96, 9, 6)[0]
    mind_r, samp_r, psi_r = ref.init_sweep_ref(
        x, cd, om, u, pp, ell=12.0, block_rows=bn)
    np.testing.assert_array_equal(np.asarray(mind_k), np.asarray(mind_r))
    np.testing.assert_array_equal(np.asarray(samp_k), np.asarray(samp_r))
    assert float(psi_k) == float(psi_r)


def test_init_sweep_candidate_padding_is_inert():
    """A pow2-padded candidate buffer with garbage rows + validity mask must
    reproduce the unpadded sweep: masked rows score +inf, never win."""
    n, d, c, cap = 200, 5, 3, 8
    x, cd, u, om = _sweep_inputs(n, d, c, seed=3)
    pad = jnp.concatenate(
        [cd, jnp.full((cap - c, d), 1e30, jnp.float32)], axis=0)
    valid = jnp.arange(cap) < c
    pp = jnp.float32(11.0)
    got = ops.init_sweep(x, pad, om, u, pp, ell=6.0, cand_valid=valid,
                         interpret=True)
    bn = specs.DEFAULT_SPEC.tile_shapes(n, d, cap)[0]
    want = ref.init_sweep_ref(x, cd, om, u, pp, ell=6.0, block_rows=bn)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    assert float(got[2]) == float(want[2])


def test_init_sweep_round0_draws_nothing():
    """psi_prev = 0 (round 0, scoring the first pick): no Bernoulli draws,
    but the potential comes back positive for round 1."""
    x, cd, u, _ = _sweep_inputs(128, 6, 1, seed=5)
    om = jnp.full((128,), jnp.inf, jnp.float32)
    mind, samp, psi = ops.init_sweep(x, cd, om, u, jnp.float32(0.0),
                                     ell=4.0, interpret=True)
    assert not bool(jnp.any(samp))
    assert float(psi) > 0.0
    assert bool(jnp.all(jnp.isfinite(mind)))


def test_init_sweep_weights_gate_draws_and_potential():
    """Zero-weight (padding) points neither contribute potential nor get
    drawn; their mind still updates (harmless, never consumed)."""
    n, d, c = 150, 4, 4
    x, cd, u, om = _sweep_inputs(n, d, c, seed=9)
    w = (jnp.arange(n) < 100).astype(jnp.float32)
    pp = jnp.float32(30.0)
    mind, samp, psi = ops.init_sweep(x, cd, om, u, pp, ell=8.0, weights=w,
                                     interpret=True)
    assert not bool(jnp.any(samp[100:]))
    expect_psi = float(jnp.sum(mind[:100]))
    assert float(psi) == pytest.approx(expect_psi, rel=1e-6)


# ------------------------------------------------------- round driver ------

def test_kmeans_parallel_kernel_matches_ref_backend():
    x = _blobs(300, 3, 4, seed=11)
    key = jax.random.key(0)
    a = kmeans_parallel_init(x, key, 4, backend="kernel")
    b = kmeans_parallel_init(x, key, 4, backend="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kmeans_parallel_invariants():
    x = _blobs(400, 5, 6, seed=13)
    cents, stats = kmeans_parallel_init(x, jax.random.key(1), 6,
                                        return_stats=True)
    assert cents.shape == (6, 5)
    assert _rows_in(x, cents)
    assert len(np.unique(np.asarray(cents), axis=0)) == 6
    assert stats["candidates"] >= 6
    # the potential is non-increasing round over round (mind only shrinks)
    psi = stats["psi"]
    assert all(b <= a * (1 + 1e-6) for a, b in zip(psi, psi[1:]))


def test_kmeans_parallel_tiny_n_tops_up():
    # n barely >= k and a stingy ell: the farthest-point top-up must still
    # deliver k distinct input rows
    x = _blobs(8, 2, 4, seed=17)
    cents = kmeans_parallel_init(x, jax.random.key(2), 4, ell=1.0, rounds=1)
    assert _rows_in(x, cents)
    assert len(np.unique(np.asarray(cents), axis=0)) == 4


def test_sharded_round_matches_single_host():
    """The distributed path (per-shard sweep + psi psum under shard_map) is
    bitwise the single-host init on a 1-device mesh — for both backends."""
    mesh = compat.make_mesh((1,), ("data",))
    x = _blobs(256, 4, 4, seed=19)
    key = jax.random.key(3)
    for backend in ("kernel", "ref"):
        host = kmeans_parallel_init(x, key, 4, backend=backend)
        dist = kmeans_parallel_init(x, key, 4, backend=backend, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(host), np.asarray(dist),
                                      err_msg=f"backend={backend}")


# ------------------------------------------------------- seed quality ------

def test_kmeans_parallel_seeds_beat_sample_on_blobs():
    """Expected (3-key mean) seed SSE on well-separated blobs: kmeans||
    covers the clusters, uniform sampling usually doubles some up."""
    x = _blobs(480, 3, 6, seed=23)
    par, smp = [], []
    for s in range(3):
        key = jax.random.key(100 + s)
        par.append(float(metrics.sse(x, kmeans_parallel_init(
            x, key, 6, backend="ref"))))
        smp.append(float(metrics.sse(x, sample_init(x, key, 6))))
    assert np.mean(par) <= np.mean(smp) * 1.01


def test_kmeans_parallel_sse_property():
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the 'dev' extra")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(2, 6), st.integers(2, 4), st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def prop(k, d, seed):
        x = _blobs(60 * k, d, k, seed=seed)
        par, smp = [], []
        for s in range(3):
            key = jax.random.fold_in(jax.random.key(seed), s)
            par.append(float(metrics.sse(x, kmeans_parallel_init(
                x, key, k, backend="ref"))))
            smp.append(float(metrics.sse(x, sample_init(x, key, k))))
        assert np.mean(par) <= np.mean(smp) * 1.01 + 1e-3

    prop()


def test_directed_iterations_to_converge_reduction():
    """Fixed seed, same data/key: kmeans|| seeds converge in strictly fewer
    Lloyd iterations AND no worse final SSE than sample seeds (the
    BENCH_kernel.json contract, in miniature)."""
    x = _blobs(512, 4, 8, seed=29)
    key = jax.random.key(5)
    solve = jax.jit(lambda p, c: get_engine("jnp").solve(
        p, c, max_iters=100, tol=1e-6))
    _, sse_p, it_p, _ = solve(x, kmeans_parallel_init(x, key, 8,
                                                      backend="ref"))
    _, sse_s, it_s, _ = solve(x, sample_init(x, key, 8))
    assert int(it_p) < int(it_s)
    assert float(sse_p) <= float(sse_s)


# ------------------------------------------- satellites: sample / k++ ------

def test_sample_init_returns_k_distinct_points():
    # regression for the top-k-of-random-keys draw: k DISTINCT indices
    x = jnp.arange(200, dtype=jnp.float32).reshape(100, 2)
    for k in (1, 7, 50, 100):
        cents = sample_init(x, jax.random.key(k), k)
        assert cents.shape == (k, 2)
        assert len(np.unique(np.asarray(cents), axis=0)) == k
        assert _rows_in(x, cents)


def test_kmeans_plus_plus_degenerate_duplicates():
    # 2 distinct rows duplicated 50x: k=2 must return both, and k=4 (> the
    # number of distinct points) must stay finite input rows, not NaN
    base = jnp.asarray([[0.0, 0.0], [5.0, 5.0]], jnp.float32)
    x = jnp.tile(base, (50, 1))
    two = kmeans_plus_plus(x, jax.random.key(0), 2)
    assert len(np.unique(np.asarray(two), axis=0)) == 2
    four = kmeans_plus_plus(x, jax.random.key(1), 4)
    assert bool(jnp.all(jnp.isfinite(four)))
    assert _rows_in(x, four)


def test_kmeans_plus_plus_weighted_ignores_zero_mass():
    x = jnp.asarray([[0.0, 0.0], [1.0, 0.0], [100.0, 100.0]], jnp.float32)
    w = jnp.asarray([1.0, 1.0, 0.0], jnp.float32)
    cents = kmeans_plus_plus(x, jax.random.key(2), 2, weights=w)
    assert not np.any(np.all(np.asarray(cents) == [100.0, 100.0], axis=1))


# ------------------------------------------------- pipeline threading ------

def test_resolve_init_dispatch_and_validation():
    x = _blobs(64, 2, 3, seed=31)
    for method in INIT_METHODS[1:]:
        cents = resolve_init(x, jax.random.key(0), 3, method)
        assert cents.shape == (3, 2)
    with pytest.raises(ValueError, match="unknown init method"):
        resolve_init(x, jax.random.key(0), 3, "given")
    with pytest.raises(ValueError, match="unknown init method"):
        resolve_init(x, jax.random.key(0), 3, "pp")


def test_kmeans_entry_point_threading():
    x = _blobs(200, 3, 4, seed=37)
    res = kmeans(x, None, params=KMeansParams(init="kmeans||", max_iters=50),
                 key=jax.random.key(0), k=4)
    assert res.centroids.shape == (4, 3)
    with pytest.raises(ValueError, match="needs key"):
        kmeans(x, None, params=KMeansParams(init="sample"), k=4)
    with pytest.raises(ValueError, match="needs k"):
        kmeans(x, None, params=KMeansParams(init="sample"),
               key=jax.random.key(0))
    with pytest.raises(ValueError, match="needs init_centroids"):
        kmeans(x, None)


def test_kmeans_batched_rejects_non_given_init():
    x = _blobs(64, 2, 2, seed=41).reshape(2, 32, 2)
    m = jnp.ones((2, 32), bool)
    c0 = x[0, :2]
    with pytest.raises(ValueError, match="requires init='given'"):
        kmeans_batched(x, m, c0, KMeansParams(init="sample"))


@pytest.mark.parametrize("strategy", ["sample", "kmeans++", "kmeans||"])
def test_ipkmeans_derives_own_seeds(strategy):
    x = _blobs(240, 3, 3, seed=43)
    cfg = IPKMeansConfig(num_clusters=3, num_subsets=2).with_init(strategy)
    assert cfg.init == strategy
    res = ipkmeans(x, None, jax.random.key(0), cfg)
    assert res.centroids.shape == (3, 3)
    assert bool(jnp.isfinite(res.sse))


def test_ipkmeans_config_rejects_unknown_init():
    cfg = IPKMeansConfig(num_clusters=3, num_subsets=2)
    with pytest.raises(ValueError, match="unknown init"):
        cfg.with_init("spectral")


def test_ipkmeans_distributed_matches_single_host_kmeanspar():
    """Acceptance: the distributed pipeline's kmeans|| seeding (sharded
    rounds) reproduces the single-host run exactly on a 1-device mesh."""
    mesh = compat.make_mesh((1,), ("data",))
    x = _blobs(240, 3, 3, seed=47)
    cfg = IPKMeansConfig(num_clusters=3, num_subsets=2).with_init("kmeans||")
    host = ipkmeans(x, None, jax.random.key(0), cfg)
    dist = ipkmeans_distributed(x, None, jax.random.key(0), cfg, mesh)
    np.testing.assert_array_equal(np.asarray(host.centroids),
                                  np.asarray(dist.centroids))
    assert float(host.sse) == float(dist.sse)
