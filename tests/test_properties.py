"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import kdtree, merge, metrics
from repro.core.kmeans import lloyd_step
from repro.distributed import compress

SET = dict(max_examples=25, deadline=None)


@st.composite
def point_sets(draw, max_n=200, max_d=4):
    n = draw(st.integers(8, max_n))
    d = draw(st.integers(1, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    return np.asarray(jax.random.normal(jax.random.key(seed), (n, d)) * 3)


@given(point_sets(), st.integers(2, 6), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_lloyd_step_never_increases_sse(pts, k, seed):
    pts = jnp.asarray(pts)
    idx = jax.random.choice(jax.random.key(seed), pts.shape[0], (k,),
                            replace=False)
    c = pts[idx]
    before = float(metrics.sse(pts, c))
    c2, _ = lloyd_step(pts, c)
    after = float(metrics.sse(pts, c2))
    assert after <= before + 1e-3 + 1e-5 * abs(before)


@given(point_sets(), st.integers(1, 5))
@settings(**SET)
def test_kdtree_is_a_partition(pts, depth):
    pts = jnp.asarray(pts)
    region = np.asarray(kdtree.build_kdtree(pts, depth))
    assert region.shape == (pts.shape[0],)
    assert region.min() >= 0 and region.max() < 2 ** depth
    counts = np.bincount(region, minlength=2 ** depth)
    # exact median splits: leaf sizes differ by at most 1 from each other
    assert counts.max() - counts.min() <= depth   # ceil-split drift bound
    assert counts.sum() == pts.shape[0]


@given(point_sets(max_n=120), st.integers(2, 8),
       st.sampled_from(["kd_axis", "kd_random", "random"]),
       st.integers(0, 2**31 - 1))
@settings(**SET)
def test_partition_pack_preserves_points(pts, m, strategy, seed):
    pts = jnp.asarray(pts)
    part = kdtree.partition_dataset(pts, jax.random.key(seed), m,
                                    strategy=strategy)
    if strategy == "random":
        cap = -(-pts.shape[0] // m)
    else:
        # leaves hold up to ceil(n / 2^depth) points (can slightly exceed
        # m by design — depth targets leaf size CLOSEST to m)
        max_leaf = -(-pts.shape[0] // (2 ** part.depth))
        cap = (2 ** part.depth) * (-(-max_leaf // m))
    packed, mask = kdtree.pack_subsets(pts, part.subset_ids, m, cap)
    assert int(mask.sum()) == pts.shape[0]
    total = float(jnp.sum(jnp.where(mask[..., None], packed, 0.0)))
    np.testing.assert_allclose(total, float(jnp.sum(pts)), rtol=1e-4,
                               atol=1e-3)


@given(st.integers(2, 20), st.integers(1, 19), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_hierarchical_merge_count_and_hull(n, k, seed):
    k = min(k, n)
    pts = jax.random.normal(jax.random.key(seed), (n, 3)) * 2
    out = np.asarray(merge.hierarchical_merge(pts, k))
    assert out.shape == (k, 3)
    # midpoints stay inside the bounding box of the inputs
    lo, hi = np.asarray(pts).min(0) - 1e-5, np.asarray(pts).max(0) + 1e-5
    assert (out >= lo).all() and (out <= hi).all()


@given(point_sets(max_n=100), st.integers(2, 5), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_assignment_is_nearest(pts, k, seed):
    from repro.kernels import ref
    pts = jnp.asarray(pts)
    idx = jax.random.choice(jax.random.key(seed), pts.shape[0], (k,),
                            replace=False)
    c = pts[idx]
    labels, mind = ref.assign_ref(pts, c)
    d2 = np.asarray(metrics.pairwise_sq_dists(pts, c))
    np.testing.assert_allclose(np.asarray(mind), d2.min(-1), rtol=1e-4,
                               atol=1e-4)


@given(st.integers(1, 512), st.floats(1e-4, 10.0),
       st.integers(0, 2**31 - 1))
@settings(**SET)
def test_int8_quantization_error_bound(n, scale, seed):
    x = jax.random.normal(jax.random.key(seed), (n,)) * scale
    q, s = compress.quantize_int8(x)
    err = np.abs(np.asarray(compress.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-7
