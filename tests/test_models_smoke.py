"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs.  (Full configs are exercised abstractly by the
dry-run only.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, SMOKE_ARCHS
from repro.launch.train import make_train_step
from repro.models import registry, transformer
from repro.models.common import Box, unbox

ARCH_NAMES = sorted(SMOKE_ARCHS)


def _batch(cfg, b=2, s=32, key=0):
    k = jax.random.key(key)
    batch = {
        "tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (b, s), 0, cfg.vocab_size),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.is_encdec or cfg.frontend == "audio_frames":
        batch["embeds"] = jax.random.normal(k, (b, 16, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = SMOKE_ARCHS[arch]
    params = registry.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    loss, metrics = registry.loss_fn(cfg)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_updates_params(arch):
    cfg = SMOKE_ARCHS[arch]
    params = registry.init_params(jax.random.key(0), cfg)
    opt_state = optim.init(params)
    step = make_train_step(cfg)
    batch = _batch(cfg)
    # step 1, not 0: the warmup schedule gives lr=0 at step 0 by design
    new_params, new_opt, metrics = step(params, opt_state, batch,
                                        jnp.int32(1))
    assert np.isfinite(float(metrics["loss"]))
    # at least one leaf moved and none became NaN
    moved = False
    for old, new in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert np.isfinite(np.asarray(new)).all()
        moved |= bool(jnp.any(old != new))
    assert moved


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_axes_cover_every_leaf(arch):
    """Every param leaf carries logical axes of matching rank (the dry-run
    sharding machinery depends on this)."""
    cfg = SMOKE_ARCHS[arch]
    boxed = registry.abstract_params(cfg)
    values, axes = unbox(boxed)
    flat_v = jax.tree.leaves(values)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_v) == len(flat_a)
    for v, a in zip(flat_v, flat_a):
        assert len(v.shape) == len(a), (v.shape, a)

    # stacked (scanned) groups must carry the 'layers' axis first — a
    # regression here silently shifts every sharding spec by one dim
    from repro.models.common import Box

    def check(node):
        if isinstance(node, Box) and node.value.ndim >= 2 \
                and len(node.axes) == node.value.ndim \
                and node.axes and node.axes[0] == "layers":
            assert node.value.shape[0] <= cfg.num_layers + cfg.encoder_layers
    jax.tree.map(check, boxed, is_leaf=lambda x: isinstance(x, Box))


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v3-671b",
                                  "recurrentgemma-9b", "xlstm-125m"])
def test_short_decode_matches_forward(arch):
    """Cheap decode-parity check for the stateful families (the full 10-arch
    24-token sweep runs in CI via tests/test_system.py)."""
    cfg = SMOKE_ARCHS[arch]
    params = registry.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    logits_tf, _, _ = transformer.forward(params, toks, cfg)
    caches = transformer.init_decode_caches(cfg, 2, 8)
    outs = []
    for t in range(8):
        lg, caches = transformer.decode_step(params, caches, toks[:, t:t + 1],
                                             jnp.int32(t), cfg)
        outs.append(lg)
    err = float(jnp.abs(logits_tf - jnp.concatenate(outs, 1)).max())
    assert err < 2e-2, err


def test_full_configs_match_assignment():
    """The exact published dims from the assignment table."""
    c = ARCHS["command-r-35b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (40, 8192, 64, 8, 22528, 256000)
    c = ARCHS["deepseek-67b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    c = ARCHS["deepseek-v3-671b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) \
        == (61, 7168, 128, 129280)
    assert c.moe.num_experts == 256 and c.moe.top_k == 8
    c = ARCHS["mixtral-8x7b"]
    assert c.moe.num_experts == 8 and c.moe.top_k == 2 and c.window == 4096
    c = ARCHS["recurrentgemma-9b"]
    assert c.recurrent.pattern == ("rglru", "rglru", "attn")
    c = ARCHS["xlstm-125m"]
    assert c.num_layers == 12 and c.d_model == 768
    c = ARCHS["seamless-m4t-large-v2"]
    assert c.encoder_layers == 24 and c.vocab_size == 256206
    c = ARCHS["chameleon-34b"]
    assert c.qk_norm and c.vocab_size == 65536
    c = ARCHS["minicpm-2b"]
    assert c.d_model == 2304 and c.vocab_size == 122753


def test_param_counts_in_published_range():
    expected = {
        "command-r-35b": (28e9, 40e9),
        "command-r-plus-104b": (95e9, 115e9),
        "deepseek-67b": (60e9, 72e9),
        "minicpm-2b": (2.2e9, 3.2e9),
        "deepseek-v3-671b": (640e9, 700e9),
        "mixtral-8x7b": (43e9, 50e9),
        "recurrentgemma-9b": (8.5e9, 12e9),
        "xlstm-125m": (0.1e9, 0.2e9),
        "chameleon-34b": (30e9, 38e9),
        "seamless-m4t-large-v2": (1.4e9, 2.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = registry.count_params_abstract(ARCHS[arch])
        assert lo <= n <= hi, (arch, n)
    # MoE active counts
    a = registry.count_params_abstract(ARCHS["deepseek-v3-671b"],
                                       active_only=True)
    assert 34e9 <= a <= 41e9
    a = registry.count_params_abstract(ARCHS["mixtral-8x7b"],
                                       active_only=True)
    assert 11e9 <= a <= 15e9
