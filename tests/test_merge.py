"""Merging stage unit tests (paper Section 2.iii)."""
import jax.numpy as jnp
import numpy as np

from repro.core import merge


def test_hierarchical_merges_closest_pair():
    pts = jnp.array([[0.0, 0.0], [0.1, 0.0], [5.0, 5.0], [9.0, 9.0]])
    out = np.asarray(merge.hierarchical_merge(pts, 3))
    # the two closest points collapse to their midpoint
    assert out.shape == (3, 2)
    assert any(np.allclose(row, [0.05, 0.0]) for row in out)
    assert any(np.allclose(row, [5.0, 5.0]) for row in out)
    assert any(np.allclose(row, [9.0, 9.0]) for row in out)


def test_hierarchical_merge_counts():
    pts = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    for k in (1, 3, 9, 10):
        assert merge.hierarchical_merge(pts, k).shape == (k, 2)


def test_hierarchical_merge_noop():
    pts = jnp.ones((4, 2))
    out = merge.hierarchical_merge(pts, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(pts))


def test_min_asse_picks_best():
    sets = jnp.stack([jnp.full((3, 2), i, jnp.float32) for i in range(4)])
    asses = jnp.array([3.0, 0.5, 2.0, 1.0])
    out = np.asarray(merge.min_asse_merge(sets, asses))
    np.testing.assert_allclose(out, np.full((3, 2), 1.0))
