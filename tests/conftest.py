import os

# tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any inherited flag from leaking in
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
