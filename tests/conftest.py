import os

# tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any inherited flag from leaking in
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _isolated_tuning_cache(tmp_path, monkeypatch):
    """Pin the tuning cache to an empty per-test path so engine parity
    results never depend on whatever winners a developer's local autotune
    runs left in experiments/tuning/ (the 'tuned' engine resolves specs
    from REPRO_TUNING_CACHE at trace time).  Tests that seed a cache set
    the env themselves, overriding this."""
    monkeypatch.setenv("REPRO_TUNING_CACHE",
                       str(tmp_path / "tuning_cache_isolated.json"))
    yield
