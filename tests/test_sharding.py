"""Sharding rules: divisibility fallbacks, per-arch overrides, spec trees."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import ARCHS
from repro.distributed import sharding as sh
from repro.models import registry


@pytest.fixture(scope="module")
def mesh():
    # logical stand-in for 16x16: a (1,1) mesh named like production; the
    # spec logic only reads names+sizes, actual placement runs in the dryrun
    return compat.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Name/shape-only mesh stand-in so tests can reason about 16x16."""
    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


def test_spec_divisible_dims():
    m = FakeMesh((16, 16), ("data", "model"))
    rules = {"vocab": ("model",), "embed": ("data",)}
    spec = sh.spec_for((256000, 8192), ("vocab", "embed"), rules, m)
    assert spec == P("model", "data")


def test_spec_indivisible_falls_back():
    m = FakeMesh((16, 16), ("data", "model"))
    rules = {"kv_heads": ("model",)}
    spec = sh.spec_for((8, 128), ("kv_heads", "head_dim"), rules, m)
    assert spec == P(None, None)          # 8 % 16 != 0 -> replicated


def test_spec_no_double_axis_use():
    m = FakeMesh((16, 16), ("data", "model"))
    rules = {"heads": ("model",), "ff": ("model",)}
    spec = sh.spec_for((64, 22528), ("heads", "ff"), rules, m)
    assert spec == P("model", None)       # second use skipped


def test_multi_axis_prefix_fallback():
    m = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    rules = {"experts": ("pod", "data", "model")}
    # 256 experts: full product 512 doesn't divide, prefix (pod,data)=32 does
    spec = sh.spec_for((256, 7168, 2048), ("experts", "embed", "expert_ff"),
                       rules, m)
    assert spec[0] == ("pod", "data")


def test_dsv3_expert_parallel_rules():
    m = FakeMesh((16, 16), ("data", "model"))
    rules = sh.rules_for(ARCHS["deepseek-v3-671b"], m)
    assert rules["experts"] == ("data", "model")
    spec = sh.spec_for((256, 7168, 2048), ("experts", "embed", "expert_ff"),
                       rules, m)
    assert spec[0] == ("data", "model")   # EP over the whole pod


def test_fsdp_on_for_big_models():
    m = FakeMesh((16, 16), ("data", "model"))
    rules_big = sh.rules_for(ARCHS["command-r-35b"], m)
    assert rules_big["embed"] == ("pod", "data")
    rules_small = sh.rules_for(ARCHS["xlstm-125m"], m)
    assert rules_small["embed"] == ()


def test_param_shardings_tree_matches(mesh):
    cfg = ARCHS["xlstm-125m"]
    boxed = registry.abstract_params(cfg)
    shardings = sh.param_shardings(boxed, cfg, mesh)
    import jax as j
    n_params = len(j.tree.leaves(boxed))
    n_shard = len(j.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_shard
