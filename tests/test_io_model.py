"""Hadoop I/O cost model: the quantities behind Fig 5 / Fig 6."""
import numpy as np

from repro.core import io_model


def test_shuffle_calibration_matches_cited_measurements():
    m = io_model.HadoopCostModel()
    # the paper cites [2]: 4s@50k, 30s@500k, 207s@5M — the linear fit must
    # pass near those points
    assert abs(m.shuffle_sec(50_000) - 4) < 4
    assert abs(m.shuffle_sec(500_000) - 30) < 10
    assert abs(m.shuffle_sec(5_000_000) - 207) < 10


def test_pkmeans_bytes_scale_with_iterations():
    m = io_model.HadoopCostModel()
    b10 = m.pkmeans_bytes(3000, 2, 5, 10)
    b20 = m.pkmeans_bytes(3000, 2, 5, 20)
    assert b20["read"] == 2 * b10["read"]
    assert b20["jobs"] == 20


def test_ipkmeans_beats_pkmeans_on_paper_config():
    """Dataset 1 geometry: 3000 pts, K=5, M=6 subsets, ~30 Lloyd iters
    (the measured regime on the Fig-4-overlap dataset)."""
    m = io_model.HadoopCostModel()
    pk = m.pkmeans_bytes(3000, 2, 5, 30)
    ipk = m.ipkmeans_bytes(3000, 2, 5, 6, kd_depth=9)
    total_pk = pk["read"] + pk["write"]
    total_ipk = ipk["read"] + ipk["write"]
    assert total_ipk < total_pk
    # the paper reports "up to 2/3 lower" — our model lands in that regime
    assert total_ipk / total_pk < 0.85


def test_io_crossover_matches_paper_caveat():
    """Paper Fig 6, experiments 2-3: when PKMeans converges in 5-8
    iterations it beats IPKMeans — the model reproduces the crossover."""
    m = io_model.HadoopCostModel()
    ipk = m.ipkmeans_bytes(3000, 2, 5, 6, kd_depth=9)
    t_ipk = ipk["read"] + ipk["write"]
    few = m.pkmeans_bytes(3000, 2, 5, 6)
    many = m.pkmeans_bytes(3000, 2, 5, 60)
    assert t_ipk > few["read"] + few["write"]      # PKMeans wins at T=6
    assert t_ipk < (many["read"] + many["write"]) * 0.45   # loses badly at 60


def test_tpu_collective_bytes_gap_is_structural():
    """TPU restatement: PKMeans all-reduces every iteration, IPKMeans's S2
    moves zero bytes — the gap grows with iteration count."""
    pk = io_model.tpu_collective_bytes_pkmeans(2, 5, iters=100,
                                               n_devices=256)
    ipk = io_model.tpu_collective_bytes_ipkmeans(3000, 2, 5, 256, 9,
                                                 n_devices=256)
    pk_long = io_model.tpu_collective_bytes_pkmeans(2, 5, iters=10_000,
                                                    n_devices=256)
    assert pk_long == 100 * pk
    assert ipk == io_model.tpu_collective_bytes_ipkmeans(
        3000, 2, 5, 256, 9, n_devices=512)   # independent of device count


def test_dcn_payload_int8ef_under_one_third_for_wide_d():
    """The pod-axis restatement of the paper's 2/3-lower-I/O headline:
    the compressed stats payload must drop under 1/3 of exact once the
    feature dim amortizes the scale sidecar (d >= 16)."""
    for d in (16, 32, 64, 256):
        ex = io_model.ipkmeans_stats_payload_bytes(16, 8, d, "exact")
        q = io_model.ipkmeans_stats_payload_bytes(16, 8, d, "int8ef")
        assert q <= ex / 3, (d, q, ex)
    # narrow d: the sidecar dominates and the ratio honestly degrades —
    # the model must NOT pretend the win is shape-independent
    assert (io_model.ipkmeans_stats_payload_bytes(16, 8, 2, "int8ef")
            > io_model.ipkmeans_stats_payload_bytes(16, 8, 2, "exact") / 3)


def test_dcn_reduce_bytes_scale_and_degenerate_cases():
    assert io_model.dcn_reduce_bytes_ipkmeans(16, 8, 32, 20, 1) == 0
    b2 = io_model.dcn_reduce_bytes_ipkmeans(16, 8, 32, 20, 2)
    b2x = io_model.dcn_reduce_bytes_ipkmeans(16, 8, 32, 40, 2)
    assert b2x == 2 * b2                 # linear in iterations
    q2 = io_model.dcn_reduce_bytes_ipkmeans(16, 8, 32, 20, 2, "int8ef")
    assert q2 * 3 <= b2                  # the ratio survives the ring factor


def test_s1_histogram_dcn_bytes_properties():
    # single pod: no DCN at all
    assert io_model.s1_histogram_dcn_bytes(10, 1) == 0
    # independent of n by construction; dominated by the leaf level, so
    # roughly doubling with depth
    b = io_model.s1_histogram_dcn_bytes(10, 2)
    b_deeper = io_model.s1_histogram_dcn_bytes(11, 2)
    assert b < b_deeper < 3 * b
    # the headline: at the production shape (n=2^26, depth=14) the
    # histogram summaries undercut ONE dataset pass by >= 10x, while the
    # sort path pays depth+1 dataset passes
    n, d, depth = 1 << 26, 64, 14
    hist = io_model.s1_histogram_dcn_bytes(depth, 4)
    sort = io_model.s1_sort_dcn_bytes(n, d, depth)
    assert hist * 10 <= n * d * 4
    assert sort == (depth + 1) * n * d * 4
    assert hist * 100 <= sort


def test_s1_sort_dcn_bytes_is_dataset_scaled():
    # the sort baseline scales with n; the histogram model does not
    assert io_model.s1_sort_dcn_bytes(2000, 8, 3) \
        == 2 * io_model.s1_sort_dcn_bytes(1000, 8, 3)
    assert io_model.s1_histogram_dcn_bytes(3, 2) \
        == io_model.s1_histogram_dcn_bytes(3, 2, rounds=8)
