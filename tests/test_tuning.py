"""KernelSpec / DeviceProfile geometry layer, the autotuning cache, and the
``tuned`` engine — all in interpret mode (the CI kernel gate).

Covers the acceptance contract of the KernelSpec subsystem: specs are the
single source of block geometry (clamping matches the historical loose-int
behaviour exactly), the resident feasibility budget comes from the device
profile (env-overridable), the JSON cache round-trips through the same
lookup path the ``tuned`` engine uses, and ``backend="tuned"`` matches the
jnp oracle whether the cache hits or misses.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import KMeansParams, kmeans
from repro.kernels import engine as engines
from repro.kernels import ops, ref, resident, specs, tuning
from repro.kernels.specs import DeviceProfile, KernelSpec


def _data(n, d, k, dtype=jnp.float32, seed=1):
    kx, kc = jax.random.split(jax.random.key(n * d * k + seed))
    x = (jax.random.normal(kx, (n, d)) * 3).astype(dtype)
    c = (jax.random.normal(kc, (k, d)) * 3).astype(dtype)
    return x, c


# ------------------------------------------------------------- KernelSpec --

def test_spec_validation_and_hashability():
    s = KernelSpec(block_n=64, block_k=64)
    assert hash(s) == hash(KernelSpec(64, 64))          # jit static arg
    assert s.replace(block_k=128).block_k == 128
    for bad in (dict(block_n=7), dict(block_n=0), dict(block_k=100),
                dict(block_k=-8), dict(acc_dtype="int8"),
                dict(acc_dtype="f32")):
        with pytest.raises(ValueError):
            KernelSpec(**bad)


def test_spec_tile_shapes_match_historical_policy():
    """The spec's clamping is byte-for-byte the policy the kernels froze as
    module constants — same blocks, same padding, for every shape the kernel
    sweeps exercise."""
    for n, d, k in [(64, 2, 3), (300, 2, 5), (1000, 17, 7), (513, 64, 130),
                    (2048, 128, 256), (96, 160, 9)]:
        bn, bk, n_pad, k_pad, d_pad = specs.DEFAULT_SPEC.tile_shapes(n, d, k)
        assert bn == min(256, max(8, n)) and bk == min(128, max(8, k))
        assert n_pad % bn == 0 and n_pad >= n
        assert k_pad % bk == 0 and k_pad >= k
        assert d_pad % 128 == 0 and d_pad >= d
        ubn, un_pad, uk_pad, ud_pad = \
            specs.UPDATE_DEFAULT_SPEC.update_tile_shapes(n, d, k)
        assert ubn == min(512, max(8, n))
        assert uk_pad >= k + 1 and uk_pad % 8 == 0


def test_spec_clamping_collapses_oversized_blocks():
    """Blocks larger than the problem clamp to it, so distinct specs can name
    the same launch geometry — the dedup rule the tuner's grid relies on."""
    small = KernelSpec(block_n=64, block_k=64)
    huge = KernelSpec(block_n=1024, block_k=512)
    assert huge.tile_shapes(48, 4, 5) == small.tile_shapes(48, 4, 5)
    x, c = _data(48, 4, 5)
    s_a, cnt_a, sse_a = ops.lloyd_step_fused(x, c, spec=huge, interpret=True)
    s_b, cnt_b, sse_b = ops.lloyd_step_fused(x, c, spec=small, interpret=True)
    np.testing.assert_array_equal(np.asarray(cnt_a), np.asarray(cnt_b))
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b), rtol=1e-6)


@pytest.mark.parametrize("spec", [
    KernelSpec(block_n=64, block_k=64),
    KernelSpec(block_n=512, block_k=256),
    KernelSpec(block_n=128, block_k=64, acc_dtype="bfloat16"),
])
def test_spec_geometry_invariance(spec):
    """Any valid spec — including bf16 on-chip accumulation — reproduces the
    oracle (the spec-level version of the loose-int invariance sweeps).

    bf16 scores legitimately flip argmin ties, moving individual points
    between clusters, so the bf16 row checks aggregate invariants (mass
    conservation, SSE within bf16 noise) rather than elementwise sums."""
    x, c = _data(300, 5, 9)
    s_r, cnt_r, sse_r = ref.lloyd_step_ref(x, c)
    s, cnt, sse = ops.lloyd_step_fused(x, c, spec=spec, interpret=True)
    if spec.acc_dtype == "bfloat16":
        assert float(cnt.sum()) == pytest.approx(300.0)   # no point lost
        np.testing.assert_allclose(np.asarray(s.sum(0)),
                                   np.asarray(s_r.sum(0)), rtol=0.05,
                                   atol=3.0)
        np.testing.assert_allclose(float(sse), float(sse_r), rtol=0.05)
    else:
        np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt_r),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(float(sse), float(sse_r), rtol=1e-4)


def test_deprecated_loose_int_shim():
    """The pre-spec kwargs still work (one release of grace), warn, and
    produce exactly the spec path's results; mixing both forms is an error."""
    x, c = _data(300, 5, 9)
    want = ops.lloyd_step_fused(
        x, c, spec=KernelSpec(block_n=128, block_k=64), interpret=True)
    with pytest.warns(DeprecationWarning, match="block_n"):
        got = ops.lloyd_step_fused(x, c, block_n=128, block_k=64,
                                   interpret=True)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(TypeError, match="not both"):
        ops.assign(x, c, spec=KernelSpec(), block_n=128)


def test_spec_vmem_models_are_monotone():
    """Bigger tiles can never price below smaller ones (the tuner's pruning
    assumes the byte models order sanely), and bf16 tiles price below f32."""
    big = KernelSpec(block_n=512, block_k=256)
    small = KernelSpec(block_n=64, block_k=64)
    n, d, k = 100_000, 64, 512
    assert big.fused_vmem_bytes(n, d, k) > small.fused_vmem_bytes(n, d, k)
    assert big.assign_vmem_bytes(n, d, k) > small.assign_vmem_bytes(n, d, k)
    bf16 = KernelSpec(block_n=512, block_k=256, acc_dtype="bfloat16")
    assert bf16.fused_vmem_bytes(n, d, k) < big.fused_vmem_bytes(n, d, k)


# ---------------------------------------------------------- DeviceProfile --

def test_profile_table_lookup():
    assert specs.get_profile("TPU v3").vmem_bytes == 16 * specs.MiB
    assert specs.get_profile("TPU v4").vmem_bytes == 32 * specs.MiB
    # longest-prefix: the lite row wins over the bare family row
    assert specs.get_profile("TPU v5 lite").device_kind == "tpu v5 lite"


def test_profile_unknown_device_kind_falls_back_conservative():
    """Unknown chips get the conservative default — whose budget is exactly
    the 12 MiB constant the resident engine used to hardcode, so behaviour
    off known TPUs is unchanged."""
    p = specs.get_profile("Weird Accelerator 9000")
    assert p.device_kind == "Weird Accelerator 9000"
    assert p.budget_bytes == 12 * specs.MiB
    assert specs.get_profile().budget_bytes == 12 * specs.MiB  # cpu host


def test_profile_env_override(monkeypatch):
    monkeypatch.setenv(specs.ENV_VMEM_BUDGET, str(1 << 20))
    assert specs.get_profile().budget_bytes == 1 << 20
    assert specs.get_profile("TPU v4").budget_bytes == 1 << 20


def test_resident_feasibility_tracks_profile_budget(monkeypatch):
    """The resident guard consults the profile, not a constant: shrinking
    the env budget flips a comfortably-feasible shape to infeasible and the
    resident engine must then take the fused fallback."""
    assert resident.resident_feasible(512, 6, 8)
    monkeypatch.setenv(specs.ENV_VMEM_BUDGET, "65536")       # 64 KiB
    assert not resident.resident_feasible(512, 6, 8)
    assert resident.max_resident_points(6, 8) < 512
    calls = {"n": 0}
    real = ops.lloyd_solve_resident

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(ops, "lloyd_solve_resident", counting)
    x, _ = _data(512, 6, 8)
    c_r, sse_r, it_r, _ = engines.get_engine("resident").solve(
        x, x[:8], max_iters=10, tol=1e-6)
    assert calls["n"] == 0                       # kernel never launched
    c_o, sse_o, it_o, _ = ref.lloyd_solve_ref(x, x[:8], max_iters=10,
                                              tol=1e-6)
    assert int(it_r) == int(it_o)
    np.testing.assert_allclose(float(sse_r), float(sse_o), rtol=1e-4)


# ------------------------------------------------------------ tuning cache --

def test_cache_roundtrip_and_schema(tmp_path):
    path = tmp_path / "kernel_specs.json"
    cache = tuning.TuningCache.load(path)
    assert cache.entries == {}
    key = tuning.cache_key("cpu", jnp.float32, 300, 2, 5)
    assert key == "cpu|float32|n512|d2|k5"       # n buckets to next pow2
    cache.put(key, KernelSpec(block_n=64, block_k=64), time_us=12.5,
              n=300, d=2, k=5, candidates=9)
    cache.save()

    obj = json.loads(path.read_text())
    assert obj["version"] == tuning.CACHE_VERSION
    entry = obj["entries"][key]
    assert entry["block_n"] == 64 and entry["block_k"] == 64
    assert entry["acc_dtype"] == "float32" and entry["time_us"] == 12.5

    fresh = tuning.TuningCache.load(path)
    assert fresh.get(key) == KernelSpec(block_n=64, block_k=64)
    assert fresh.get("cpu|float32|n512|d9|k9") is None


def test_cache_rejects_wrong_version_and_garbage(tmp_path):
    vpath = tmp_path / "wrong_version.json"
    vpath.write_text(json.dumps({"version": 99, "entries": {"k": {}}}))
    with pytest.warns(UserWarning, match="version"):
        assert tuning.TuningCache.load(vpath).entries == {}
    gpath = tmp_path / "garbage.json"
    gpath.write_text("{not json")
    with pytest.warns(UserWarning, match="unreadable"):
        assert tuning.TuningCache.load(gpath).entries == {}
    mpath = tmp_path / "malformed_entry.json"
    mpath.write_text(json.dumps({
        "version": tuning.CACHE_VERSION,
        "entries": {"key": {"block_n": 7, "block_k": 64}}}))  # invalid spec
    cache = tuning.TuningCache.load(mpath)
    with pytest.warns(UserWarning, match="malformed"):
        assert cache.get("key") is None


def test_candidate_specs_prune_by_budget_and_dedup():
    roomy = DeviceProfile("test", 16 * specs.MiB)
    n, d, k = 200_000, 256, 2048
    cands = tuning.candidate_specs(n, d, k, roomy)
    geoms = {(c.tile_shapes(n, d, k), c.acc_dtype) for c in cands}
    assert len(geoms) == len(cands)              # no duplicate geometries
    tiny = DeviceProfile("test", 1 << 16)        # 64 KiB: prunes everything
    only = tuning.candidate_specs(n, d, k, tiny)
    assert only == [specs.DEFAULT_SPEC]          # fallback always survives
    small = tuning.candidate_specs(48, 4, 5, roomy)
    assert len(small) < len(cands)               # clamping collapses the grid


def test_autotune_step_records_winner(tmp_path):
    """With an injected measure the sweep is deterministic: the known-best
    candidate must win and land in the cache under the right key."""
    profile = DeviceProfile("testchip", 16 * specs.MiB)
    cache = tuning.TuningCache.load(tmp_path / "c.json")

    def measure(spec):                            # block_n=128 rigged to win
        return 1.0 if spec.block_n == 128 else 2.0 + spec.block_n / 1e3

    best, rows = tuning.autotune_step(300, 4, 16, profile=profile,
                                      cache=cache, measure=measure)
    assert best.block_n == 128
    assert rows[0]["time_us"] <= rows[-1]["time_us"]
    key = tuning.cache_key("testchip", jnp.float32, 300, 4, 16)
    assert cache.get(key) == best
    cache.save()
    assert tuning.TuningCache.load(cache.path).get(key) == best


def test_autotune_step_real_measure_interpret(tmp_path):
    """End-to-end sweep on a tiny shape through the actual fused kernel in
    interpret mode (what the CI autotune smoke runs)."""
    cache = tuning.TuningCache.load(tmp_path / "c.json")
    best, rows = tuning.autotune_step(
        64, 4, 4, cache=cache, repeats=1, interpret=True,
        block_ns=(64, 128), block_ks=(64,))
    assert best in [r["spec"] for r in rows]
    assert cache.entries                         # winner recorded


# ------------------------------------------------------------ tuned engine --

def _seed_cache(monkeypatch, tmp_path, n, d, k, spec,
                dtype=jnp.float32):
    """Point REPRO_TUNING_CACHE at a fresh cache holding ``spec`` for the
    local device kind, and reload the in-process memo."""
    path = tmp_path / "kernel_specs.json"
    cache = tuning.TuningCache.load(path)
    kind = specs.get_profile().device_kind
    cache.put(tuning.cache_key(kind, dtype, n, d, k), spec)
    cache.save()
    monkeypatch.setenv(tuning.ENV_CACHE_PATH, str(path))
    tuning.reload_cache()
    return cache


def test_tuned_engine_resolves_cached_spec(monkeypatch, tmp_path):
    n, d, k = 288, 6, 12
    seeded = KernelSpec(block_n=64, block_k=64)
    _seed_cache(monkeypatch, tmp_path, n, d, k, seeded)
    eng = engines.get_engine("tuned")
    x, c = _data(n, d, k)
    assert eng.resolve_spec(x, c) == seeded
    # a different shape misses the cache -> None -> module defaults
    x2, c2 = _data(n, d, k + 1)
    assert eng.resolve_spec(x2, c2) is None


def test_tuned_engine_parity_with_cached_spec(monkeypatch, tmp_path):
    """backend='tuned' with a NON-default cached geometry still matches the
    jnp oracle through the whole KMeansResult — tuning changes the launch
    shape, never the math."""
    n, d, k = 352, 6, 8
    _seed_cache(monkeypatch, tmp_path, n, d, k,
                KernelSpec(block_n=64, block_k=64))
    x, _ = _data(n, d, k)
    init = x[:k]
    r_tun = kmeans(x, init, params=KMeansParams(max_iters=25,
                                                backend="tuned"))
    r_jnp = kmeans(x, init, params=KMeansParams(max_iters=25))
    assert int(r_tun.iters) == int(r_jnp.iters)
    assert bool(r_tun.converged) == bool(r_jnp.converged)
    np.testing.assert_allclose(np.asarray(r_tun.centroids),
                               np.asarray(r_jnp.centroids),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(r_tun.sse), float(r_jnp.sse), rtol=1e-4)


def test_tuned_engine_default_fallback_parity():
    """Cache miss (no cache seeded): tuned == resident == oracle on a fresh
    shape — 'tuned' is always safe to request."""
    n, d, k = 416, 5, 7
    x, _ = _data(n, d, k)
    init = x[:k]
    r_tun = kmeans(x, init, params=KMeansParams(max_iters=20,
                                                backend="tuned"))
    r_jnp = kmeans(x, init, params=KMeansParams(max_iters=20))
    assert int(r_tun.iters) == int(r_jnp.iters)
    np.testing.assert_allclose(np.asarray(r_tun.centroids),
                               np.asarray(r_jnp.centroids),
                               rtol=1e-4, atol=1e-4)


def test_lookup_unknown_device_kind_returns_none(monkeypatch, tmp_path):
    _seed_cache(monkeypatch, tmp_path, 64, 4, 4, KernelSpec(64, 64))
    assert tuning.lookup_spec(64, 4, 4,
                              device_kind="weird chip 9000") is None


# -------------------------------------------------------- BACKENDS snapshot --

def test_backends_sees_late_registrations():
    """core.kmeans.BACKENDS is computed per-access, so engines registered
    after core's import (the tuned engine, custom user engines) are never
    invisible."""
    import sys
    km = sys.modules["repro.core.kmeans"]
    assert "tuned" in km.BACKENDS

    class Late(engines.LloydEngine):
        name = "_late_test"

        def step(self, points, centroids, weights=None):
            return ref.lloyd_step_ref(points, centroids, weights)

    engines.register(Late())
    try:
        assert "_late_test" in km.BACKENDS
    finally:
        engines._REGISTRY.pop("_late_test", None)
    assert "_late_test" not in km.BACKENDS
    with pytest.raises(AttributeError):
        km.NOT_A_THING
