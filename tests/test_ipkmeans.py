"""Integration tests: IPKMeans pipeline vs PKMeans — the paper's claims."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import (IPKMeansConfig, ipkmeans, ipkmeans_distributed,
                        io_model, pkmeans)
from repro.data import (gaussian_mixture, initial_centroid_groups,
                        paper_dataset_3000)


@pytest.fixture(scope="module")
def dataset():
    pts, _ = paper_dataset_3000(0)
    inits = initial_centroid_groups(pts, 5, groups=3)
    return pts, inits


def test_sse_parity_with_pkmeans(dataset):
    """Table 1: IPKMeans SSE within a fraction of a percent of PKMeans."""
    pts, inits = dataset
    for init in inits:
        ref = pkmeans(pts, init)
        res = ipkmeans(pts, init, jax.random.key(0),
                       IPKMeansConfig(num_clusters=5, num_subsets=6))
        gap = (float(res.sse) - float(ref.sse)) / float(ref.sse)
        assert gap < 0.02, f"SSE gap {gap:.4f} exceeds 2%"


def test_fewer_parallel_rounds_than_pkmeans(dataset):
    """The O(log n + 1) vs per-iteration-job claim: kd_depth+2 'jobs' vs
    PKMeans' Lloyd-iteration count, with I/O bytes to match (Fig 5)."""
    pts, inits = dataset
    ref = pkmeans(pts, inits[0])
    res = ipkmeans(pts, inits[0], jax.random.key(0),
                   IPKMeansConfig(num_clusters=5, num_subsets=6))
    model = io_model.HadoopCostModel()
    pk = model.pkmeans_bytes(3000, 2, 5, int(ref.iters))
    ipk = model.ipkmeans_bytes(3000, 2, 5, 6, res.kd_depth)
    assert ipk["jobs"] == res.kd_depth + 2
    # paper: "up to 2/3 lower I/O overheads"
    total_pk = pk["read"] + pk["write"]
    total_ipk = ipk["read"] + ipk["write"]
    assert total_ipk < total_pk


def test_variant_ranking(dataset):
    """Fig 8 directionality: kd+axis+minASSE beats global random partition
    on average over seeds/inits."""
    pts, inits = dataset
    gaps = {"kd_axis": [], "random": []}
    for s, init in enumerate(inits):
        ref = float(pkmeans(pts, init).sse)
        for variant in gaps:
            cfg = IPKMeansConfig(num_clusters=5, num_subsets=12,
                                 partition=variant)
            r = ipkmeans(pts, init, jax.random.key(s), cfg)
            gaps[variant].append(float(r.sse) / ref - 1.0)
    assert np.mean(gaps["kd_axis"]) <= np.mean(gaps["random"]) + 1e-4


def test_more_subsets_trade_accuracy(dataset):
    """Table 2 trend: more reducers => SSE non-decreasing (roughly)."""
    pts, inits = dataset
    sses = []
    for m in (6, 24, 96):
        cfg = IPKMeansConfig(num_clusters=5, num_subsets=m)
        r = ipkmeans(pts, inits[0], jax.random.key(0), cfg)
        sses.append(float(r.sse))
    assert sses[-1] >= sses[0] * 0.999


def test_merge_variants_agree_roughly(dataset):
    """min-ASSE tracks PKMeans closely; hierarchical merging is looser —
    the paper's own Section 3(v) finding ('good centroids may be merged by
    bad centroids, so the result is not stable')."""
    pts, inits = dataset
    ref = float(pkmeans(pts, inits[0]).sse)
    bounds = {"min_asse": 1.05, "hierarchical": 1.60}
    for merge, bound in bounds.items():
        cfg = IPKMeansConfig(num_clusters=5, num_subsets=6, merge=merge)
        r = ipkmeans(pts, inits[0], jax.random.key(0), cfg)
        assert float(r.sse) / ref < bound, (merge, float(r.sse) / ref)


def test_distributed_matches_reference(dataset):
    """shard_map S2 on a 1-device mesh == pure vmap pipeline (the multi-
    device equivalence is covered by the dry-run + the 8-device CI run)."""
    pts, inits = dataset
    mesh = compat.make_mesh((1,), ("data",))
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    r_d = ipkmeans_distributed(pts, inits[0], jax.random.key(0), cfg,
                               mesh, ("data",))
    r_s = ipkmeans(pts, inits[0], jax.random.key(0), cfg)
    np.testing.assert_allclose(np.asarray(r_d.centroids),
                               np.asarray(r_s.centroids), rtol=1e-5)


def test_subset_iterations_are_independent(dataset):
    """Reducers converge at different iteration counts — proof the solvers
    are not lock-stepped (the paper's core scheduling property)."""
    pts, inits = dataset
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=12)
    r = ipkmeans(pts, inits[0], jax.random.key(0), cfg)
    iters = np.asarray(r.subset_iters)
    assert iters.min() >= 1
    assert len(np.unique(iters)) > 1


# ----------------------------------------------------------- pack strategy --

def test_pack_sorted_parity_when_subsets_full():
    """IPKMeansConfig.pack='sorted' (one sort+reshape, no scatter — §Perf
    C2, previously reachable only from kmeans_dryrun): with a balanced
    random partition every subset holds exactly `capacity` points, the
    sorted pack is valid, and the pipeline must reproduce the scatter pack
    bit-for-bit."""
    pts = jax.random.normal(jax.random.key(0), (512, 4))
    init = pts[:5]
    base = IPKMeansConfig(num_clusters=5, num_subsets=4, partition="random")
    r_scatter = ipkmeans(pts, init, jax.random.key(1), base)
    r_sorted = ipkmeans(pts, init, jax.random.key(1),
                        dataclasses.replace(base, pack="sorted"))
    np.testing.assert_allclose(np.asarray(r_sorted.centroids),
                               np.asarray(r_scatter.centroids), rtol=1e-6)
    np.testing.assert_allclose(float(r_sorted.sse), float(r_scatter.sse),
                               rtol=1e-6)


def test_pack_sorted_falls_back_when_uneven(dataset):
    """n != M * capacity (the kd partition's padded leaves) violates the
    sorted pack's static precondition — the config must fall back to the
    scatter pack instead of tripping the kernel's assert."""
    pts, inits = dataset
    base = IPKMeansConfig(num_clusters=5, num_subsets=6)
    assert pts.shape[0] != 6 * base.subset_capacity(pts.shape[0])
    r_scatter = ipkmeans(pts, inits[0], jax.random.key(0), base)
    r_sorted = ipkmeans(pts, inits[0], jax.random.key(0),
                        dataclasses.replace(base, pack="sorted"))
    np.testing.assert_allclose(np.asarray(r_sorted.centroids),
                               np.asarray(r_scatter.centroids), rtol=1e-6)


def test_pack_a2a_single_process_falls_back(dataset):
    """pack='a2a' needs a mesh; the single-process entry point has none and
    must take the scatter path — WITH a warning saying so (the distributed
    path wires the mesh through — covered by the 8-device slow test for
    the kernel itself)."""
    pts, inits = dataset
    base = IPKMeansConfig(num_clusters=5, num_subsets=6)
    r_scatter = ipkmeans(pts, inits[0], jax.random.key(0), base)
    with pytest.warns(RuntimeWarning, match="needs a device mesh"):
        r_a2a = ipkmeans(pts, inits[0], jax.random.key(0),
                         dataclasses.replace(base, pack="a2a"))
    np.testing.assert_allclose(np.asarray(r_a2a.centroids),
                               np.asarray(r_scatter.centroids), rtol=1e-6)


def test_pack_a2a_distributed_parity(dataset):
    """The distributed pipeline threads its mesh into the a2a pack (1-device
    mesh: all_to_all degenerates but the code path is the production one)."""
    pts, inits = dataset
    mesh = compat.make_mesh((1,), ("data",))
    base = IPKMeansConfig(num_clusters=5, num_subsets=6)
    r_scatter = ipkmeans_distributed(pts, inits[0], jax.random.key(0),
                                     base, mesh, ("data",))
    r_a2a = ipkmeans_distributed(pts, inits[0], jax.random.key(0),
                                 dataclasses.replace(base, pack="a2a"),
                                 mesh, ("data",))
    np.testing.assert_allclose(np.asarray(r_a2a.centroids),
                               np.asarray(r_scatter.centroids), rtol=1e-5)


def test_pack_unknown_raises(dataset):
    pts, inits = dataset
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6, pack="zip")
    with pytest.raises(ValueError, match="unknown pack"):
        ipkmeans(pts, inits[0], jax.random.key(0), cfg)


def test_reduce_mode_validation(dataset):
    pts, inits = dataset
    with pytest.raises(ValueError, match="unknown reduce"):
        IPKMeansConfig(num_clusters=5, num_subsets=6, reduce="bf16")
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    assert cfg.with_reduce("int8ef").reduce == "int8ef"
    assert cfg.reduce == "exact"                 # with_reduce didn't mutate
    # compressed reduction without a pod axis is meaningless — S2 has no
    # reduction at all on the single mesh (the paper's claim) — and must
    # fail loudly rather than silently run exact
    mesh = compat.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="needs pod_axis"):
        ipkmeans_distributed(pts, inits[0], jax.random.key(0),
                             cfg.with_reduce("int8ef"), mesh, ("data",))


def test_pod_axis_validation(dataset):
    pts, inits = dataset
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    mesh = compat.make_mesh((1,), ("data",))
    # pod_axis must be a real mesh axis outside axis_names
    with pytest.raises(ValueError, match="pod_axis"):
        ipkmeans_distributed(pts, inits[0], jax.random.key(0), cfg,
                             mesh, ("data",), pod_axis="data")
    with pytest.raises(ValueError, match="pod_axis"):
        ipkmeans_distributed(pts, inits[0], jax.random.key(0), cfg,
                             mesh, ("data",), pod_axis="pods")
    # reseed_empty needs a global subset view; the pod path shards points
    rs = dataclasses.replace(
        cfg, kmeans=cfg.kmeans._replace(reseed_empty=True))
    from repro.distributed.sharding import kmeans_pod_mesh
    pmesh = kmeans_pod_mesh(1, 1)
    with pytest.raises(ValueError, match="reseed_empty"):
        ipkmeans_distributed(pts, inits[0], jax.random.key(0), rs,
                             pmesh, ("data",), pod_axis="pods")


def test_cross_pod_solve_single_pod_matches_reference(dataset):
    """The cross-pod S2 on a trivial 1x1 pod mesh must reproduce the
    single-mesh result exactly (the 8-device 2x4 case is the slow
    multidevice test)."""
    pts, inits = dataset
    from repro.distributed.sharding import kmeans_pod_mesh
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    # the pod path auto-resolves s1="histogram": the reference must run the
    # same S1 order for iteration-exact agreement
    ref = ipkmeans(pts, inits[0], jax.random.key(0),
                   cfg.with_s1("histogram"))
    pmesh = kmeans_pod_mesh(1, 1)
    res = ipkmeans_distributed(pts, inits[0], jax.random.key(0), cfg,
                               pmesh, ("data",), pod_axis="pods")
    np.testing.assert_allclose(np.asarray(res.centroids),
                               np.asarray(ref.centroids), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res.subset_iters),
                                  np.asarray(ref.subset_iters))


def test_s1_mode_validation_and_auto_resolution(dataset):
    pts, inits = dataset
    with pytest.raises(ValueError, match="unknown s1"):
        IPKMeansConfig(num_clusters=5, num_subsets=6, s1="radix")
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    assert cfg.s1 == "auto"
    assert cfg.with_s1("histogram").s1 == "histogram"
    assert cfg.s1 == "auto"                          # with_s1 didn't mutate
    # auto == sort off the pod path: bit-identical to an explicit "sort"
    r_auto = ipkmeans(pts, inits[0], jax.random.key(0), cfg)
    r_sort = ipkmeans(pts, inits[0], jax.random.key(0), cfg.with_s1("sort"))
    np.testing.assert_array_equal(np.asarray(r_auto.centroids),
                                  np.asarray(r_sort.centroids))
    # explicit histogram S1 runs end to end and clusters comparably
    r_hist = ipkmeans(pts, inits[0], jax.random.key(0),
                      cfg.with_s1("histogram"))
    assert abs(float(r_hist.sse) - float(r_sort.sse)) / float(r_sort.sse) \
        < 0.05


def test_check_pack_complete_raises_on_loss():
    from repro.core.ipkmeans import _check_pack_complete
    full = jnp.ones((4, 8), bool)
    _check_pack_complete(32, full, None, "scatter")          # no loss: ok
    _check_pack_complete(32, full, jnp.int32(0), "a2a")
    with pytest.raises(ValueError, match="dropped 2 of 34"):
        _check_pack_complete(34, full, None, "scatter")
    with pytest.raises(ValueError, match="dropped 3 of 32"):
        _check_pack_complete(32, full, jnp.int32(3), "a2a")
    # under tracing the counts are abstract — must not raise
    jax.jit(lambda m: _check_pack_complete(99, m, None, "scatter") or 0)(full)
