"""Checkpoint: atomic commit, roundtrip, async overlap, GC, elastic load."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
import pytest

from repro.checkpoint import manager as ckpt


@pytest.fixture()
def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "count": jnp.int32(7)}


def test_roundtrip(tmp_path, tree):
    ckpt.save(tmp_path, 3, tree)
    got = ckpt.restore(tmp_path, 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_uncommitted_checkpoint_ignored(tmp_path, tree):
    ckpt.save(tmp_path, 1, tree)
    p = ckpt.save(tmp_path, 2, tree)
    (p / "COMMITTED").unlink()          # simulate crash mid-commit
    assert ckpt.latest_step(tmp_path) == 1
    step, _ = ckpt.restore_latest(tmp_path, tree)
    assert step == 1


def test_shape_mismatch_rejected(tmp_path, tree):
    ckpt.save(tmp_path, 1, tree)
    bad = dict(tree, w=jnp.zeros((2, 2)))
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, 1, bad)


def test_gc_keeps_latest(tmp_path, tree):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree)
    ckpt.gc_old(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    steps = sorted(int(p.name.split("_")[1])
                   for p in Path(tmp_path).glob("step_*"))
    assert steps == [4, 5]


def test_async_checkpointer(tmp_path, tree):
    w = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (10, 20):
        w.save(s, tree)
    w.wait()
    assert ckpt.latest_step(tmp_path) == 20
    got = ckpt.restore(tmp_path, 20, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


def test_elastic_resharding(tmp_path, tree):
    """A checkpoint written under one sharding restores under another
    (mesh-shape change) — leaves are stored logically."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh1 = compat.make_mesh((1,), ("data",))
    sharded = jax.device_put(tree, NamedSharding(mesh1, P()))
    ckpt.save(tmp_path, 1, sharded)
    mesh2 = compat.make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh2, P()), tree)
    got = ckpt.restore(tmp_path, 1, tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
    assert got["w"].sharding.mesh.axis_names == ("data", "model")


def test_train_resume_is_exact(tmp_path):
    """10 straight steps == 5 steps + crash + resume of 5 (checkpoint/
    restart determinism, the core fault-tolerance guarantee)."""
    from repro.configs import SMOKE_ARCHS
    from repro.launch.train import train_loop
    cfg = SMOKE_ARCHS["xlstm-125m"]
    d1 = tmp_path / "a"
    p1, _, _ = train_loop(cfg, steps=6, global_batch=2, seq_len=16,
                          ckpt_dir=str(d1), ckpt_every=100, log_every=100)
    d2 = tmp_path / "b"
    train_loop(cfg, steps=3, global_batch=2, seq_len=16,
               ckpt_dir=str(d2), ckpt_every=3, log_every=100)
    p2, _, _ = train_loop(cfg, steps=6, global_batch=2, seq_len=16,
                          ckpt_dir=str(d2), ckpt_every=100, log_every=100)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
