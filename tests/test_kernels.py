"""Pallas kernel sweeps: shapes x dtypes against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (64, 2, 3),        # tiny, d < lane
    (300, 2, 5),       # the paper's own geometry
    (1000, 17, 7),     # odd everything
    (513, 64, 130),    # k crosses one block boundary
    (2048, 128, 256),  # aligned, multi-block in n and k
    (96, 160, 9),      # d > 128 (two lane groups)
]


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_assign_matches_ref(n, d, k, dtype):
    kx, kc = jax.random.split(jax.random.key(n * d * k))
    x = (jax.random.normal(kx, (n, d)) * 3).astype(dtype)
    c = (jax.random.normal(kc, (k, d)) * 3).astype(dtype)
    l_ref, m_ref = ref.assign_ref(x, c)
    l_pl, m_pl = ops.assign(x, c, interpret=True)
    # labels must agree except where two centroids tie within fp noise
    d2 = np.asarray(jax.vmap(
        lambda xi: jnp.sum((c.astype(jnp.float32) - xi) ** 2, -1))(
            x.astype(jnp.float32)))
    ref_l, pl_l = np.asarray(l_ref), np.asarray(l_pl)
    diff = ref_l != pl_l
    if diff.any():
        a = d2[np.arange(n)[diff], ref_l[diff]]
        b = d2[np.arange(n)[diff], pl_l[diff]]
        np.testing.assert_allclose(a, b, rtol=5e-2)
    tol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(m_ref), np.asarray(m_pl),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d,k", SHAPES[:4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_centroid_update_matches_ref(n, d, k, dtype):
    kx, kw = jax.random.split(jax.random.key(n + d + k))
    x = (jax.random.normal(kx, (n, d)) * 2).astype(dtype)
    labels = jax.random.randint(jax.random.key(5), (n,), 0, k)
    w = (jax.random.uniform(kw, (n,)) > 0.2).astype(jnp.float32)
    s_ref, c_ref = ref.centroid_update_ref(x, labels, w, k)
    s_pl, c_pl = ops.centroid_update(x, labels, w, k, interpret=True)
    tol = 1e-3 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pl),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(c_ref), np.asarray(c_pl),
                               rtol=1e-5)


@pytest.mark.parametrize("block_n,block_k", [(128, 128), (256, 64), (64, 256)])
def test_assign_block_shape_invariance(block_n, block_k):
    from repro.kernels.specs import KernelSpec
    x = jax.random.normal(jax.random.key(0), (700, 16))
    c = jax.random.normal(jax.random.key(1), (200, 16))
    l0, m0 = ref.assign_ref(x, c)
    l1, m1 = ops.assign(x, c, spec=KernelSpec(block_n=block_n,
                                              block_k=block_k),
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    np.testing.assert_allclose(np.asarray(m0), np.asarray(m1), rtol=1e-4,
                               atol=1e-4)


def test_assign_weak_type_and_jit_cache():
    """wrapper is jit-stable across python float inputs (no weak-type
    recompiles) and supports vmap."""
    x = jnp.ones((32, 4))
    c = jnp.zeros((3, 4))
    l, m = ops.assign(x, c, interpret=True)
    assert l.dtype == jnp.int32 and m.dtype == jnp.float32
    batched = jax.vmap(lambda xx: ref.assign_ref(xx, c)[0])(
        jnp.stack([x, x + 1]))
    assert batched.shape == (2, 32)
