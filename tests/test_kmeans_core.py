"""Unit tests: Lloyd solver, PKMeans reference, masking, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KMeansParams, ipkmeans, IPKMeansConfig, kmeans,
                        kmeans_batched, metrics, pkmeans)
from repro.core.kmeans import lloyd_step
from repro.data import gaussian_mixture, initial_centroid_groups


@pytest.fixture(scope="module")
def data():
    pts, centers, _ = gaussian_mixture(jax.random.key(0), 600, 4)
    inits = initial_centroid_groups(pts, 4, groups=2)
    return pts, centers, inits


def test_lloyd_step_decreases_sse(data):
    pts, _, inits = data
    c = inits[0]
    prev = float(metrics.sse(pts, c))
    for _ in range(5):
        c, _ = lloyd_step(pts, c)
        cur = float(metrics.sse(pts, c))
        assert cur <= prev + 1e-3
        prev = cur


def test_kmeans_converges(data):
    pts, _, inits = data
    res = kmeans(pts, inits[0])
    assert bool(res.converged)
    # converged => one more Lloyd step barely moves centroids
    c2, _ = lloyd_step(pts, res.centroids)
    assert float(metrics.centroid_shift(c2, res.centroids)) < 1e-3


def test_kmeans_masked_equals_subset(data):
    pts, _, inits = data
    n = 400
    mask = jnp.arange(pts.shape[0]) < n
    r_masked = kmeans(pts, inits[0], mask=mask)
    r_subset = kmeans(pts[:n], inits[0])
    np.testing.assert_allclose(np.asarray(r_masked.centroids),
                               np.asarray(r_subset.centroids), rtol=1e-5)
    np.testing.assert_allclose(float(r_masked.sse), float(r_subset.sse),
                               rtol=1e-5)


def test_empty_cluster_keeps_centroid():
    pts = jnp.array([[0.0, 0.0], [0.1, 0.0], [0.0, 0.1]])
    # third centroid far away never wins a point
    init = jnp.array([[0.0, 0.0], [0.1, 0.1], [100.0, 100.0]])
    res = kmeans(pts, init, params=KMeansParams(max_iters=5))
    np.testing.assert_allclose(np.asarray(res.centroids[2]),
                               [100.0, 100.0], rtol=1e-6)
    assert np.isfinite(np.asarray(res.centroids)).all()


def test_pkmeans_matches_kmeans(data):
    pts, _, inits = data
    r1 = pkmeans(pts, inits[0])
    r2 = kmeans(pts, inits[0])
    np.testing.assert_allclose(np.asarray(r1.centroids),
                               np.asarray(r2.centroids), rtol=1e-5)
    assert int(r1.iters) == int(r2.iters)


def test_batched_matches_loop(data):
    pts, _, inits = data
    subsets = jnp.stack([pts[:300], pts[300:]])
    masks = jnp.ones((2, 300), bool)
    rb = kmeans_batched(subsets, masks, inits[0])
    for i in range(2):
        ri = kmeans(subsets[i], inits[0])
        np.testing.assert_allclose(np.asarray(rb.centroids[i]),
                                   np.asarray(ri.centroids), rtol=1e-5)


def test_pallas_backend_matches_jnp(data):
    pts, _, inits = data
    r_j = kmeans(pts, inits[0], params=KMeansParams(backend="jnp"))
    r_p = kmeans(pts, inits[0], params=KMeansParams(backend="pallas"))
    assert int(r_j.iters) == int(r_p.iters)
    np.testing.assert_allclose(np.asarray(r_j.centroids),
                               np.asarray(r_p.centroids), rtol=1e-4,
                               atol=1e-4)
