"""Serving tier: bucketing/padding correctness, jit-cache boundedness,
mini-batch refresh semantics, and endpoint smoke (core/serve.py +
launch/serve_kmeans.py + engine.update_minibatch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KMeansParams, update_minibatch
from repro.core.serve import BucketPolicy, NearestCentroidServer
from repro.kernels import engine as engines
from repro.kernels import ops, ref


def _data(n, d, k, seed=0):
    kx, kc = jax.random.split(jax.random.key(seed + n * d * k))
    return (jax.random.normal(kx, (n, d)) * 3.0,
            jax.random.normal(kc, (k, d)) * 3.0)


# ------------------------------------------------------------ bucketing --

def test_bucket_policy_pow2():
    pol = BucketPolicy(min_bucket=8, max_bucket=128)
    assert [pol.bucket_for(n) for n in (1, 8, 9, 63, 64, 65, 128)] == \
        [8, 8, 16, 64, 64, 128, 128]
    assert pol.buckets() == (8, 16, 32, 64, 128)
    with pytest.raises(ValueError, match="max_bucket"):
        pol.bucket_for(129)
    with pytest.raises(ValueError, match="n >= 1"):
        pol.bucket_for(0)


def test_bucket_policy_fixed():
    pol = BucketPolicy(kind="fixed", ladder=(32, 256))
    assert pol.bucket_for(5) == 32
    assert pol.bucket_for(33) == 256
    assert pol.top == 256
    assert pol.buckets() == (32, 256)
    with pytest.raises(ValueError, match="ladder"):
        BucketPolicy(kind="fixed").bucket_for(4)
    with pytest.raises(ValueError, match="unknown bucket policy"):
        BucketPolicy(kind="pow3").bucket_for(4)


@pytest.mark.parametrize("n", [1, 3, 8, 17, 64, 100, 150])
def test_padded_assign_bitwise_vs_unpadded(n):
    """The acceptance contract: a bucketed (zero-padded) serving call must
    return, for the real rows, exactly what the unpadded kernel returns —
    bit for bit, labels and distances."""
    q, c = _data(n, 5, 13, seed=n)
    server = NearestCentroidServer(
        c, policy=BucketPolicy(min_bucket=8, max_bucket=64))
    labels, mind = server.assign(q)
    labels0, mind0 = ops.lloyd_assign_fused(q, c)
    assert np.array_equal(np.asarray(labels), np.asarray(labels0))
    assert np.array_equal(np.asarray(mind), np.asarray(mind0))
    # and against the oracle's labels (argmin semantics, low-index ties)
    lr, _ = ref.assign_ref(q, c)
    assert np.array_equal(np.asarray(labels), np.asarray(lr))


def test_jit_cache_bounded_under_mixed_stream():
    """A mixed-size request stream may compile at most ONE entry per bucket
    — revisiting a size, or any new size inside a seen bucket, must not
    retrace."""
    _, c = _data(8, 4, 6)
    server = NearestCentroidServer(
        c, policy=BucketPolicy(min_bucket=8, max_bucket=64))
    sizes = [3, 9, 17, 64, 150, 5, 33, 9, 3, 12, 64, 1, 40, 150]
    for i, n in enumerate(sizes):
        q, _ = _data(n, 4, 6, seed=i)
        server.assign(q)
    assert set(server.trace_counts) <= set(server.policy.buckets())
    assert all(v == 1 for v in server.trace_counts.values()), \
        server.trace_counts


def test_coalesced_dispatch_matches_direct():
    """submit + step packs queued requests into one launch; per-ticket
    results must equal the direct per-request path exactly."""
    _, c = _data(8, 4, 6)
    server = NearestCentroidServer(
        c, policy=BucketPolicy(min_bucket=8, max_bucket=64))
    qs = [_data(n, 4, 6, seed=50 + n)[0] for n in (4, 7, 11)]
    tickets = [server.submit(q) for q in qs]
    done = server.step()
    assert sorted(done) == sorted(tickets)          # 22 rows pack into 32
    assert server.pending == 0
    for t, q in zip(tickets, qs):
        labels, mind = server.result(t)
        l0, m0 = ops.lloyd_assign_fused(q, c)
        assert np.array_equal(np.asarray(labels), np.asarray(l0))
        assert np.array_equal(np.asarray(mind), np.asarray(m0))
    with pytest.raises(KeyError):
        server.result(tickets[0])                   # results pop once


def test_step_leaves_overflow_queued():
    _, c = _data(8, 4, 6)
    server = NearestCentroidServer(
        c, policy=BucketPolicy(min_bucket=8, max_bucket=16))
    t1 = server.submit(_data(10, 4, 6, seed=1)[0])
    t2 = server.submit(_data(12, 4, 6, seed=2)[0])  # 22 > top bucket 16
    assert server.step() == [t1]
    assert server.pending == 1
    assert server.step() == [t2]


# ---------------------------------------------------- mini-batch refresh --

def test_update_minibatch_fused_matches_oracle():
    x, c = _data(257, 7, 5)
    counts = jnp.abs(jax.random.normal(jax.random.key(7), (5,))) * 10.0
    oc, on, osse = update_minibatch(x, c, counts)
    fc, fn, fsse = update_minibatch(x, c, counts,
                                    params=KMeansParams(backend="fused"))
    np.testing.assert_allclose(np.asarray(fc), np.asarray(oc),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fn), np.asarray(on), rtol=1e-6)
    np.testing.assert_allclose(float(fsse), float(osse), rtol=1e-5)


def test_update_minibatch_is_sculleys_sequential_update():
    """The closed-form merge must equal the literal Sculley loop: walk the
    batch point by point with eta = 1/count, assignments fixed at batch
    start."""
    x, c = _data(101, 3, 4)
    counts = jnp.asarray([5.0, 0.0, 17.0, 2.0])
    labels, _ = ref.assign_ref(x, c)
    cs = np.asarray(c, np.float64)
    cn = np.asarray(counts, np.float64)
    for i in range(x.shape[0]):
        j = int(labels[i])
        cn[j] += 1.0
        eta = 1.0 / cn[j]
        cs[j] = (1.0 - eta) * cs[j] + eta * np.asarray(x[i], np.float64)
    new_c, new_counts, _ = update_minibatch(x, c, counts)
    np.testing.assert_allclose(np.asarray(new_c), cs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_counts), cn, rtol=1e-6)


def test_update_minibatch_untouched_centers_bitwise():
    """Centers the batch never reaches keep their coordinates bit-for-bit
    (the merge's where-guard, not a c*n/n round trip) and their counts."""
    x, c = _data(64, 4, 6)
    c = c.at[3].set(1e6)                            # unreachable center
    counts = jnp.full((6,), 3.0)
    new_c, new_counts, _ = update_minibatch(x, c, counts,
                                            params=KMeansParams(
                                                backend="fused"))
    assert np.array_equal(np.asarray(new_c[3]), np.asarray(c[3]))
    assert float(new_counts[3]) == 3.0


def test_update_minibatch_mask_rows_ignored():
    x, c = _data(80, 4, 5)
    mask = jnp.arange(80) < 50
    a = update_minibatch(x[:50], c, jnp.zeros((5,)))
    b = update_minibatch(x, c, jnp.zeros((5,)), mask)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               rtol=1e-6)


def test_refresh_sse_non_increasing_on_fixed_stream():
    """Repeated refreshes against the SAME batch must not increase its SSE:
    each merge moves every touched center toward its assigned mean (convex
    combination), then the next round may only reassign to closer centers."""
    x, c = _data(300, 6, 8, seed=11)
    server = NearestCentroidServer(c, refresh_backend="fused")
    for _ in range(6):
        server.refresh(x)
    series = server.refresh_sse
    assert len(series) == 6
    for a, b in zip(series, series[1:]):
        assert b <= a * (1.0 + 1e-6), series


def test_refresh_improves_on_drifted_stream():
    """On a shifted batch, one refresh must score better than the stale
    centroids it replaced (the serving tier's reason to exist)."""
    x, c = _data(400, 5, 6, seed=21)
    shifted = x + 2.0
    server = NearestCentroidServer(c, refresh_backend="fused")
    sse_before = float(server.refresh(shifted))     # scores INCOMING c
    _, mind = ref.assign_ref(shifted, server.centroids)
    assert float(jnp.sum(mind)) < sse_before


def test_refresh_does_not_retrace_serving_buckets():
    """Refreshes change centroid VALUES, never shapes — the serving
    jit cache must be untouched."""
    x, c = _data(128, 4, 6)
    server = NearestCentroidServer(c)
    server.assign(x[:10])
    before = dict(server.trace_counts)
    server.refresh(x)
    server.assign(x[:10])
    server.assign(x[:9])                            # same bucket, new size
    assert server.trace_counts == before


# ------------------------------------------------------------- endpoint --

def test_endpoint_smoke():
    """launch/serve_kmeans.py --smoke end to end: the CI serve-smoke job's
    entry point (it asserts the one-trace-per-bucket contract internally)."""
    from repro.launch import serve_kmeans
    server = serve_kmeans.main(["--smoke"])
    assert server.refresh_sse                       # refreshes ran
    assert all(v == 1 for v in server.trace_counts.values())
