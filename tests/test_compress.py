"""int8 error-feedback compression: quantizer degeneracies, per-axis
scales, the stats-tree generalization, and the cross-pod ef_allreduce
(exercised single-device via vmap's axis_name)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import compress


def test_quantize_zero_block_regression():
    """An all-zero block used to produce a degenerate scale (NaNs on the
    f16 path where the old 1e-12 clamp underflowed); it must now round-trip
    to EXACT zeros in every dtype.  Empty clusters hit this every
    iteration."""
    for dtype in (jnp.float32, jnp.float16, jnp.bfloat16):
        q, scale = compress.quantize_int8(jnp.zeros((4, 8), dtype))
        out = compress.dequantize_int8(q, scale)
        assert not np.any(np.isnan(np.asarray(out)))
        np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_quantize_axiswise_scales_and_error_bound():
    """axis=-1 gives one scale per row; quantization error is bounded by
    scale/2 per element, and an all-zero row stays exact even when other
    rows are huge (it must not inherit their scale)."""
    x = jnp.stack([jnp.zeros((8,)), 1000.0 * jnp.ones((8,)),
                   jnp.linspace(-3.0, 3.0, 8)])
    q, scale = compress.quantize_int8(x, axis=-1)
    assert scale.shape == (3, 1)
    out = np.asarray(compress.dequantize_int8(q, scale))
    np.testing.assert_array_equal(out[0], 0.0)
    err = np.abs(out - np.asarray(x))
    assert np.all(err <= np.asarray(scale) * 0.5 + 1e-6)


def test_compress_tree_stats_residual_feedback():
    """Quantizing the SAME stats tree repeatedly with EF keeps the running
    mean of the dequantized values unbiased (the residual re-injects what
    int8 dropped), which is what makes the Lloyd fixed point exact."""
    tree = {"sums": jnp.full((2, 4, 8), 0.3141),
            "counts": jnp.full((2, 4), 7.77)}
    axes = {"sums": -1, "counts": -1}
    state = compress.init_ef(tree)
    acc = jax.tree.map(jnp.zeros_like, tree)
    steps = 50
    for _ in range(steps):
        payload, state = compress.compress_tree(tree, state, axes=axes)
        deq = jax.tree.map(lambda p: compress.dequantize_int8(*p), payload,
                           is_leaf=lambda x: isinstance(x, tuple))
        acc = jax.tree.map(jnp.add, acc, deq)
    for name in ("sums", "counts"):
        mean = np.asarray(acc[name]) / steps
        np.testing.assert_allclose(mean, np.asarray(tree[name]), rtol=1e-2)


def test_ef_allreduce_matches_exact_within_bound():
    """Under vmap(axis_name) — the single-device stand-in for the pod
    shard_map — the compressed reduction lands within the reported error
    bound of the exact sum, and all programs hold the same reduced tree."""
    key = jax.random.PRNGKey(0)
    pods = 4
    sums = jax.random.normal(key, (pods, 3, 5, 16)) * 50.0
    counts = jnp.abs(jax.random.normal(jax.random.key(1), (pods, 3, 5))) * 20
    tree = {"sums": sums, "counts": counts}
    axes = {"sums": -1, "counts": -1}

    def body(local):
        state = compress.init_ef(local)
        return compress.ef_allreduce(local, state, "p", axes=axes,
                                     return_error_bound=True)

    red, _, err = jax.vmap(body, axis_name="p")(tree)
    exact = jax.tree.map(lambda leaf: jnp.sum(leaf, axis=0), tree)
    for name in ("sums", "counts"):
        per_pod = np.asarray(red[name])
        # every pod holds the same reduced tree
        for p in range(1, pods):
            np.testing.assert_array_equal(per_pod[p], per_pod[0])
        gap = np.abs(per_pod[0] - np.asarray(exact[name]))
        assert np.all(gap <= np.asarray(err[name])[0] + 1e-5)


def test_ef_allreduce_zero_rows_stay_exact():
    """All-zero sums rows (empty clusters) must reduce to exact zeros —
    the quantizer's zero-scale guard end to end through the collective."""
    pods = 2
    sums = jnp.ones((pods, 2, 4, 8)) * 100.0
    sums = sums.at[:, :, 0, :].set(0.0)          # cluster 0 empty everywhere
    tree = {"sums": sums, "counts": jnp.zeros((pods, 2, 4))}

    def body(local):
        state = compress.init_ef(local)
        red, _ = compress.ef_allreduce(local, state, "p",
                                       axes={"sums": -1, "counts": -1})
        return red

    red = jax.vmap(body, axis_name="p")(tree)
    np.testing.assert_array_equal(np.asarray(red["sums"])[:, :, 0, :], 0.0)
    np.testing.assert_array_equal(np.asarray(red["counts"]), 0.0)


def test_stats_payload_under_one_third_of_exact():
    """The wire payload of the int8ef stats tree (int8 values + f32
    scales) must sit at <= 1/3 of the f32 tree for d=32 — the ratio the
    pod-scaling bench snapshots."""
    m, k, d = 16, 8, 32
    stats = {"sums": jnp.zeros((m, k, d), jnp.float32),
             "counts": jnp.zeros((m, k), jnp.float32)}
    exact = compress.payload_bytes(stats)
    payload, _ = compress.compress_tree(stats, compress.init_ef(stats),
                                        axes={"sums": -1, "counts": -1})
    assert compress.payload_bytes(payload) <= exact / 3


def test_compress_grads_back_compat():
    """The original gradient entry point still works: per-tensor scales,
    decompress matches within scale/2."""
    grads = {"w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8),
             "b": jnp.zeros((8,))}
    payload, state = compress.compress_grads(grads, compress.init_ef(grads))
    out = compress.decompress_grads(payload)
    for name in ("w", "b"):
        q, scale = payload[name]
        assert np.asarray(scale).shape == ()        # per-tensor
        err = np.abs(np.asarray(out[name]) - np.asarray(grads[name]))
        assert np.all(err <= float(scale) * 0.5 + 1e-7)


def test_quantize_unknown_mode_payload_pricing():
    from repro.core import io_model
    with pytest.raises(ValueError):
        io_model.ipkmeans_stats_payload_bytes(4, 8, 16, "bf16")
