"""Multi-device correctness: these paths need >1 device, so each test runs
a small script in a subprocess with XLA_FLAGS host-device virtualization
(the main pytest process must keep seeing exactly 1 device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_script(body: str, devices: int = 8):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import PartitionSpec as P
        from repro import compat
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=560,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # force the CPU backend: without this, a
                              # machine with libtpu spends minutes probing
                              # TPU metadata before falling back
                              "JAX_PLATFORMS": "cpu"})
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_ipkmeans_distributed_8dev_matches_reference():
    run_script("""
        from repro.core import IPKMeansConfig, ipkmeans, ipkmeans_distributed
        from repro.data import paper_dataset_3000, initial_centroid_groups
        pts, _ = paper_dataset_3000(0)
        init = initial_centroid_groups(pts, 5, groups=1)[0]
        mesh = compat.make_mesh((8,), ("data",))
        cfg = IPKMeansConfig(num_clusters=5, num_subsets=24)
        r_d = ipkmeans_distributed(pts, init, jax.random.key(0), cfg,
                                   mesh, ("data",))
        r_s = ipkmeans(pts, init, jax.random.key(0), cfg)
        np.testing.assert_allclose(np.asarray(r_d.centroids),
                                   np.asarray(r_s.centroids), rtol=1e-5)
    """)


@pytest.mark.slow
def test_moe_a2a_and_local_dispatch_match_dense_2x2():
    run_script("""
        from repro.configs.base import MoEConfig
        from repro.models import moe
        mesh = compat.make_mesh((2, 2), ("data", "model"))
        d, E, ff, B, S = 32, 8, 64, 4, 16
        base = MoEConfig(num_experts=E, top_k=2, d_ff_expert=ff,
                         dispatch="dense", capacity_factor=8.0)
        p = moe.init_moe(jax.random.key(1), d, base, jnp.float32)
        x = jax.random.normal(jax.random.key(0), (B, S, d), jnp.float32)
        ref, _ = moe.moe_ffn(x, p, base)
        for disp in ("a2a", "local"):
            with compat.set_mesh(mesh):
                out, _ = jax.jit(lambda x, p: moe.moe_ffn(
                    x, p, dataclasses.replace(base, dispatch=disp)))(x, p)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
    """, devices=4)


@pytest.mark.slow
def test_pack_subsets_a2a_matches_reference_8dev():
    run_script("""
        from repro.core import kdtree
        mesh = compat.make_mesh((8,), ("data",))
        n, d, M = 2048, 4, 32
        pts = jax.random.normal(jax.random.key(0), (n, d))
        part = kdtree.partition_dataset(pts, jax.random.key(1), M)
        cap = 2 ** part.depth
        ref_p, ref_m = kdtree.pack_subsets(pts, part.subset_ids, M, cap)
        a_p, a_m, dropped = kdtree.pack_subsets_a2a(pts, part.subset_ids, M,
                                                    cap, mesh, ("data",))
        assert int(dropped) == 0
        assert int(a_m.sum()) == n
        for s in range(M):
            a = np.asarray(ref_p[s][np.asarray(ref_m[s])])
            b = np.asarray(a_p[s][np.asarray(a_m[s])])
            np.testing.assert_allclose(a[np.lexsort(a.T)],
                                       b[np.lexsort(b.T)], rtol=1e-6)
    """)


def test_histogram_builder_matches_sort_builder():
    # single-device: pure algorithmic equivalence (ties included)
    import jax
    import jax.numpy as jnp
    from repro.core import kdtree
    pts = jax.random.normal(jax.random.key(2), (777, 3)) * 5
    pts = pts.at[100:200, 0].set(pts[0, 0])         # force ties
    for depth in (1, 4, 7):
        a = kdtree.build_kdtree(pts, depth)
        b = kdtree.build_kdtree_histogram(pts, depth)
        assert bool(jnp.all(a == b)), depth


@pytest.mark.slow
def test_ipkmeans_cross_pod_2x4_exact_and_int8ef():
    """The multi-pod S2 on a real 2x4 pods x devices mesh: the exact
    reduction must match the single-process reference, and int8ef must
    land within 1e-3 relative SSE of exact (the BENCH_dist gate, asserted
    here as a correctness property)."""
    run_script("""
        from repro.core import IPKMeansConfig, ipkmeans, ipkmeans_distributed
        from repro.data import paper_dataset_3000, initial_centroid_groups
        from repro.distributed.sharding import (KMEANS_DATA_AXIS,
                                                KMEANS_POD_AXIS,
                                                kmeans_pod_mesh)
        pts, _ = paper_dataset_3000(0)
        init = initial_centroid_groups(pts, 5, groups=1)[0]
        cfg = IPKMeansConfig(num_clusters=5, num_subsets=8)
        # the pod path auto-resolves s1="histogram", so the single-process
        # reference must run the same (bucketed-rank) S1 order
        ref = ipkmeans(pts, init, jax.random.key(0),
                       cfg.with_s1("histogram"))
        mesh = kmeans_pod_mesh(2, 4)
        ex = ipkmeans_distributed(pts, init, jax.random.key(0), cfg, mesh,
                                  (KMEANS_DATA_AXIS,),
                                  pod_axis=KMEANS_POD_AXIS)
        np.testing.assert_allclose(np.asarray(ex.centroids),
                                   np.asarray(ref.centroids),
                                   rtol=1e-5, atol=1e-5)
        q = ipkmeans_distributed(pts, init, jax.random.key(0),
                                 cfg.with_reduce("int8ef"), mesh,
                                 (KMEANS_DATA_AXIS,),
                                 pod_axis=KMEANS_POD_AXIS)
        rel = abs(float(q.sse) - float(ex.sse)) / float(ex.sse)
        assert rel <= 1e-3, rel
    """)


@pytest.mark.parametrize("shape,axes", [((8,), ("data",)),
                                        ((2, 4), ("pods", "data"))])
def test_s1_sharded_bitwise_parity(shape, axes):
    """Sharded build + labeler vs the single-device references, bit for bit:
    duplicate coordinates forcing tie-breaks, an all-points-equal leaf,
    depth=0, and uneven n that doesn't divide the shard count — on both a
    flat (8,) and a 2-D (2, 4) pods x devices mesh (one subprocess each:
    the depth>0 sharded-build compiles are the slow part).  Even n and
    deeper trees ride the end-to-end slow test below."""
    run_script(f"""
        from repro.core import kdtree
        mesh = compat.make_mesh({shape!r}, {axes!r})
        axes = {axes!r}
        rng = np.random.default_rng(0)
        pts = jnp.asarray(rng.normal(size=(777, 3)).astype(np.float32))
        pts = pts.at[50:150, 0].set(pts[0, 0])      # duplicate coords: ties
        cases = [pts, jnp.ones((256, 2), jnp.float32)]   # + all points equal
        key = jax.random.PRNGKey(7)
        for pts in cases:
            for depth in (0, 3):
                ref_r = kdtree.build_kdtree_histogram(pts, depth)
                ref_l = kdtree.label_regions_histogram(
                    pts, ref_r, key, 2 ** depth, 4)
                r = kdtree.build_kdtree_histogram_sharded(
                    pts, depth, mesh, axes)
                assert np.array_equal(np.asarray(r), np.asarray(ref_r)), (
                    pts.shape, depth, axes)
                l = kdtree.label_regions_histogram_sharded(
                    pts, ref_r, 2 ** depth, 4, mesh, axes)
                assert np.array_equal(np.asarray(l), np.asarray(ref_l)), (
                    pts.shape, depth, axes)
        print("parity ok")
    """)


@pytest.mark.slow
def test_partition_dataset_sharded_2x4_end_to_end():
    """partition_dataset on the 2x4 pods x devices mesh: bit-identical ids
    to the single-device histogram path, and the pod a2a pack loses
    nothing (dropped == 0, per-subset contents match the scatter pack)."""
    run_script("""
        from jax.sharding import NamedSharding
        from repro.core import kdtree
        from repro.distributed.sharding import (KMEANS_DATA_AXIS,
                                                KMEANS_POD_AXIS,
                                                kmeans_pod_mesh,
                                                s1_point_spec)
        mesh = kmeans_pod_mesh(2, 4)
        axes = (KMEANS_POD_AXIS, KMEANS_DATA_AXIS)
        n, d, M = 4096, 4, 16
        pts = jax.random.normal(jax.random.key(0), (n, d))
        pts = jax.device_put(pts, NamedSharding(
            mesh, s1_point_spec((KMEANS_DATA_AXIS,), KMEANS_POD_AXIS)))
        key = jax.random.key(1)
        ref = kdtree.partition_dataset(pts, key, M, leaf_capacity=256,
                                       builder="histogram",
                                       labeler="histogram")
        got = kdtree.partition_dataset(pts, key, M, leaf_capacity=256,
                                       builder="histogram",
                                       labeler="histogram",
                                       mesh=mesh, axis_names=axes)
        assert got.depth == ref.depth
        assert np.array_equal(np.asarray(got.region_ids),
                              np.asarray(ref.region_ids))
        assert np.array_equal(np.asarray(got.subset_ids),
                              np.asarray(ref.subset_ids))
        cap = 512       # pod-slack: mean per (pod, subset) is 128
        a_p, a_m, dropped = kdtree.pack_subsets_a2a(
            pts, got.subset_ids, M, cap, mesh, (KMEANS_DATA_AXIS,),
            pod_axis=KMEANS_POD_AXIS)
        assert int(dropped) == 0
        assert int(a_m.sum()) == n
        s_p, s_m = kdtree.pack_subsets(pts, got.subset_ids, M, cap)
        for s in range(M):
            a = np.asarray(a_p[s][np.asarray(a_m[s])])
            b = np.asarray(s_p[s][np.asarray(s_m[s])])
            np.testing.assert_allclose(a[np.lexsort(a.T)],
                                       b[np.lexsort(b.T)], rtol=1e-6)
        print("sharded partition ok")
    """)
