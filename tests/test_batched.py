"""Batched-resident S2 megakernel: one pipelined launch per reducer stack.

The contract under test (see kernels/batch_resident.py): ``solve_batched``
on an (M, S, d) stack lowers to a SINGLE ``pallas_call`` and matches the
vmap-of-resident oracle bit-for-bit on centroids/SSE/iters/converged —
including groups whose subsets converge at different iterations, all-padding
subsets (ASSE=+inf), bf16 carries, and the fused fallback when even a T=1
group busts the VMEM budget.  All in interpret mode (the CI kernel gate).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import KMeansParams, kmeans_batched
from repro.kernels import batch_resident, ops, ref, specs, tuning
from repro.kernels import engine as engines


def _stack(m, s, d, k, dtype=jnp.float32, scale=3.0, seed=1):
    kx, kc = jax.random.split(jax.random.key(m * s * d * k + seed))
    x = (jax.random.normal(kx, (m, s, d)) * scale).astype(dtype)
    c = (jax.random.normal(kc, (k, d)) * scale).astype(dtype)
    return x, c


def _assert_results_equal(a, b):
    """Bit-for-bit equality across the whole stacked KMeansResult."""
    for field, va, vb in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(va, np.float32) if va.dtype == jnp.bfloat16 else
            np.asarray(va),
            np.asarray(vb, np.float32) if vb.dtype == jnp.bfloat16 else
            np.asarray(vb),
            err_msg=field)


def _count_pallas_eqns(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if "pallas" in eqn.primitive.name:
            n += 1
        for v in eqn.params.values():
            for u in (v if isinstance(v, (tuple, list)) else (v,)):
                if type(u).__name__ in ("Jaxpr", "ClosedJaxpr"):
                    n += _count_pallas_eqns(getattr(u, "jaxpr", u))
    return n


# ------------------------------------------------------------ registration --

def test_batched_engine_registered():
    assert "batched" in engines.available()
    eng = engines.get_engine("batched")
    assert eng.name == "batched"
    # single solves inherit the resident path — only stacks change
    assert isinstance(eng, engines.ResidentEngine)


# ------------------------------------------------------- single-launch form --

@pytest.mark.parametrize("reseed", [False, True])
def test_stack_lowers_to_single_pallas_call(reseed):
    """The acceptance contract: a whole (M, S, d) stack is ONE pallas_call
    in the jaxpr — the per-reducer launches are gone, not hidden.  With
    ``reseed_empty=True`` too: the farthest-point reseed runs inside the
    megakernel's group loop, not in a host-side fallback."""
    x, c = _stack(6, 64, 3, 4)
    w = jnp.ones((6, 64), jnp.float32)
    eng = engines.get_engine("batched")
    jaxpr = jax.make_jaxpr(lambda s_, w_, c_: eng.solve_batched(
        s_, c_, w_, max_iters=10, tol=1e-6, reseed_empty=reseed))(x, w, c)
    assert _count_pallas_eqns(jaxpr.jaxpr) == 1


def test_group_padding_handles_indivisible_stacks():
    """M not a multiple of T pads with zero-weight subsets that are sliced
    off — every real lane still matches its single-subset resident solve."""
    m, s, d, k = 7, 48, 3, 4
    x, c = _stack(m, s, d, k)
    got = ops.lloyd_solve_batched(x, c, group_t=3, max_iters=20, tol=1e-6)
    for i in range(m):
        want = ops.lloyd_solve_resident(x[i], c, max_iters=20, tol=1e-6)
        for g, w_ in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g[i]), np.asarray(w_))


# ----------------------------------------------- parity vs the vmap oracle --

@pytest.mark.parametrize("m,s,d,k", [(4, 64, 2, 3), (6, 96, 5, 8),
                                     (3, 57, 17, 7)])
@pytest.mark.parametrize("masked", [False, True])
def test_batched_matches_vmap_resident_oracle(m, s, d, k, masked):
    """backend='batched' == backend='resident' (the vmap-of-solve path)
    bit-for-bit through the whole stacked KMeansResult."""
    x, c = _stack(m, s, d, k)
    masks = jnp.ones((m, s), bool)
    if masked:
        masks = (jax.random.uniform(jax.random.key(7), (m, s)) > 0.25)
    p = KMeansParams(max_iters=30)
    r_bat = kmeans_batched(x, masks, c, p._replace(backend="batched"))
    r_vm = kmeans_batched(x, masks, c, p._replace(backend="resident"))
    _assert_results_equal(r_bat, r_vm)


def test_heterogeneous_convergence_in_one_group():
    """A subset converging on its first trip shares ONE group with a subset
    that runs to max_iters: the finished lane must freeze (bit-for-bit its
    solo solve) while its groupmate keeps iterating."""
    s, d, k = 16, 2, 2
    fast = jnp.concatenate([jnp.zeros((8, d)), jnp.full((8, d), 10.0)])
    slow = jax.random.normal(jax.random.key(4), (s, d)) * 5
    x = jnp.stack([fast, slow])
    init = jnp.array([[0.0, 0.0], [10.0, 10.0]])       # exact means of `fast`
    got = ops.lloyd_solve_batched(x, init, group_t=2, max_iters=2, tol=1e-6)
    assert int(got[2][0]) == 1 and bool(got[3][0])     # converged on trip 1
    assert int(got[2][1]) == 2 and not bool(got[3][1])  # hit max_iters
    for i in range(2):
        want = ops.lloyd_solve_resident(x[i], init, max_iters=2, tol=1e-6)
        for g, w_ in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g[i]), np.asarray(w_))


def test_all_padding_subset_keeps_asse_inf():
    """An empty (all-padding) subset must converge immediately with sse=0
    and ASSE=+inf — it can never win the min-ASSE merge — on both paths."""
    m, s, d, k = 4, 32, 2, 3
    x, c = _stack(m, s, d, k)
    masks = jnp.ones((m, s), bool).at[2].set(False)
    p = KMeansParams(max_iters=15)
    r_bat = kmeans_batched(x, masks, c, p._replace(backend="batched"))
    r_vm = kmeans_batched(x, masks, c, p._replace(backend="resident"))
    _assert_results_equal(r_bat, r_vm)
    assert float(r_bat.sse[2]) == 0.0
    assert np.isinf(float(r_bat.asse[2]))
    assert int(r_bat.iters[2]) == 1 and bool(r_bat.converged[2])


def test_bf16_carry_roundtrip():
    """bf16 stacks round-trip the centroid carry through the caller's dtype
    every iteration exactly like the single-subset kernel, so the batched
    and vmap paths stay bit-for-bit identical in bf16 too."""
    m, s, d, k = 4, 64, 4, 4
    x, c = _stack(m, s, d, k, dtype=jnp.bfloat16)
    masks = jnp.ones((m, s), bool).at[1, 40:].set(False)
    p = KMeansParams(max_iters=25)
    r_bat = kmeans_batched(x, masks, c, p._replace(backend="batched"))
    r_vm = kmeans_batched(x, masks, c, p._replace(backend="resident"))
    assert r_bat.centroids.dtype == jnp.bfloat16
    _assert_results_equal(r_bat, r_vm)


def test_batched_solve_hits_max_iters():
    x, c = _stack(3, 48, 3, 4)
    _, _, it, conv = ops.lloyd_solve_batched(x, c, max_iters=3, tol=0.0)
    assert all(int(i) == 3 for i in it)
    assert not any(bool(v) for v in conv)


def test_hypothesis_batched_vs_vmap_oracle():
    """hypothesis sweep: random stacks/masks/dtypes/group sizes — the
    megakernel vs the vmap oracle, bit-for-bit, every example."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the 'dev' extra (pip install -e '.[dev]')")
    from hypothesis import given, settings, strategies as st

    @given(st.sampled_from([(3, 48, 2, 3), (5, 64, 3, 4), (4, 40, 5, 6)]),
           st.sampled_from([jnp.float32, jnp.bfloat16]),
           st.booleans(), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def prop(shape, dtype, masked, seed):
        m, s, d, k = shape
        x, c = _stack(m, s, d, k, dtype=dtype, seed=seed % 1000)
        masks = jnp.ones((m, s), bool)
        if masked:
            masks = (jax.random.uniform(jax.random.key(seed % 997),
                                        (m, s)) > 0.3)
        p = KMeansParams(max_iters=12)
        r_bat = kmeans_batched(x, masks, c, p._replace(backend="batched"))
        r_vm = kmeans_batched(x, masks, c, p._replace(backend="resident"))
        _assert_results_equal(r_bat, r_vm)

    prop()


# --------------------------------------------------- feasibility + sizing --

def test_group_vmem_model_and_sizing():
    s, d, k = 258, 64, 64                        # paper-sized subsets
    b1 = batch_resident.batched_group_vmem_bytes(1, s, d, k)
    b4 = batch_resident.batched_group_vmem_bytes(4, s, d, k)
    assert b4 > b1                               # monotone in T
    budget = specs.get_profile().budget_bytes
    t = batch_resident.batched_group_size(1024, s, d, k)
    assert t >= 1
    # fills the budget: chosen T fits, T+1 does not (or the stack capped it)
    assert batch_resident.batched_group_vmem_bytes(t, s, d, k) <= budget
    if t < 1024:
        assert batch_resident.batched_group_vmem_bytes(t + 1, s, d, k) \
            > budget
    # a subset too large for even one group: infeasible, size 0
    assert not batch_resident.batched_feasible(4096, 8, 2048)
    assert batch_resident.batched_group_size(64, 4096, 8, 2048) == 0
    # the DeviceProfile hook agrees with the module-level function
    assert specs.get_profile().batched_group_size(1024, s, d, k) == t


def test_spec_group_t_validation_and_roundtrip():
    assert specs.KernelSpec().group_t is None
    spec = specs.KernelSpec(group_t=4)
    assert specs.KernelSpec.from_json(spec.to_json()) == spec
    # None stays absent from JSON so version-1 caches keep their schema
    assert "group_t" not in specs.KernelSpec().to_json()
    for bad in (0, -2, 2.5):
        with pytest.raises(ValueError, match="group_t"):
            specs.KernelSpec(group_t=bad)


def test_auto_group_size_refuses_infeasible_stack(monkeypatch):
    """With no explicit group_t, an infeasible stack must raise — never
    silently clamp to T=1 and launch a kernel the budget cannot hold (an
    explicit group_t remains the caller's responsibility)."""
    monkeypatch.setenv(specs.ENV_VMEM_BUDGET, "16384")       # 16 KiB
    x, c = _stack(3, 64, 4, 4)
    with pytest.raises(ValueError, match="no feasible group size"):
        ops.lloyd_solve_batched(x, c, max_iters=5, tol=1e-6)
    # explicit override still runs (interpret mode has no real VMEM)
    _, _, it, _ = ops.lloyd_solve_batched(x, c, group_t=1, max_iters=2,
                                          tol=0.0)
    assert all(int(i) == 2 for i in it)


def test_fallback_when_group_over_budget(monkeypatch):
    """When even a T=1 group busts the budget the engine must route the
    stack through the vmap-of-solve path (never launching the megakernel)
    and still match the jnp oracle."""
    def boom(*args, **kwargs):
        raise AssertionError("batched kernel launched on infeasible stack")

    monkeypatch.setattr(ops, "lloyd_solve_batched", boom)
    monkeypatch.setenv(specs.ENV_VMEM_BUDGET, "16384")       # 16 KiB
    m, s, d, k = 3, 64, 4, 4
    x, c = _stack(m, s, d, k)
    assert not batch_resident.batched_feasible(s, d, k)
    got = engines.get_engine("batched").solve_batched(
        x, c, max_iters=10, tol=1e-6)
    for i in range(m):
        want = ref.lloyd_solve_ref(x[i], c, max_iters=10, tol=1e-6)
        assert int(got[2][i]) == int(want[2])
        np.testing.assert_allclose(np.asarray(got[0][i]),
                                   np.asarray(want[0]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(got[1][i]), float(want[1]),
                                   rtol=1e-4)


def test_reseed_empty_stays_on_megakernel(monkeypatch):
    """Reseeding now runs INSIDE the group loop: the stack must keep the
    megakernel path (never the vmap-of-solve fallback the flag used to
    force) — and still rescue the frozen centroid in every subset."""
    def boom(self, *args, **kwargs):
        raise AssertionError("reseed_empty forced the vmap-of-solve fallback")

    monkeypatch.setattr(engines.LloydEngine, "solve_batched", boom)
    pts = jnp.concatenate([
        jax.random.normal(jax.random.key(0), (30, 2)),
        jax.random.normal(jax.random.key(1), (30, 2)) + 10.0])
    x = jnp.stack([pts, pts + 0.5])
    masks = jnp.ones((2, 60), bool)
    init = jnp.array([[0.0, 0.0], [0.5, 0.5], [500.0, 500.0]])
    res = kmeans_batched(x, masks, init,
                         KMeansParams(max_iters=20, backend="batched",
                                      reseed_empty=True))
    assert float(jnp.abs(res.centroids[:, 2]).max()) < 50.0


# ----------------------------------------------------- tuned group size T --

def _seed_stack_cache(monkeypatch, tmp_path, s, d, k, m, group_t):
    path = tmp_path / "kernel_specs.json"
    cache = tuning.TuningCache.load(path)
    kind = specs.get_profile().device_kind
    cache.put(tuning.cache_key(kind, jnp.float32, s, d, k, m=m),
              specs.DEFAULT_SPEC.replace(group_t=group_t))
    cache.save()
    monkeypatch.setenv(tuning.ENV_CACHE_PATH, str(path))
    tuning.reload_cache()


def test_cached_group_t_overrides_budget(monkeypatch, tmp_path):
    m, s, d, k = 8, 64, 4, 4
    _seed_stack_cache(monkeypatch, tmp_path, s, d, k, m, group_t=2)
    assert tuning.lookup_group_t(s, d, k, m) == 2
    eng = engines.get_engine("batched")
    assert eng.resolve_group_size(m, s, d, k, jnp.float32) == 2
    # a cached winner from a roomier chip clamps to the local budget's cap
    _seed_stack_cache(monkeypatch, tmp_path, s, d, k, m, group_t=10 ** 6)
    cap = batch_resident.batched_group_size(m, s, d, k)
    assert eng.resolve_group_size(m, s, d, k, jnp.float32) == cap


def test_candidate_group_ts_prune_and_fill():
    roomy = specs.DeviceProfile("test", 64 * specs.MiB)
    cands = tuning.candidate_group_ts(64, 256, 8, 16, roomy)
    assert cands == sorted(set(cands))
    cap = batch_resident.batched_group_size(64, 256, 8, 16,
                                            roomy.budget_bytes)
    assert cap in cands                          # fill-the-budget competes
    assert all(
        batch_resident.batched_group_vmem_bytes(t, 256, 8, 16)
        <= roomy.budget_bytes for t in cands)
    tiny = specs.DeviceProfile("test", 1 << 14)
    assert tuning.candidate_group_ts(64, 256, 8, 16, tiny) == []


def test_autotune_batched_records_winner(tmp_path):
    """With an injected measure the group sweep is deterministic: the rigged
    winner lands in the cache under the |m<bucket> key with group_t set."""
    profile = specs.DeviceProfile("testchip", 64 * specs.MiB)
    cache = tuning.TuningCache.load(tmp_path / "c.json")

    def measure(t):                               # t=2 rigged to win
        return 1.0 if t == 2 else 2.0 + t / 100.0

    best, rows = tuning.autotune_batched(8, 64, 4, 4, profile=profile,
                                         cache=cache, group_ts=(1, 2, 4),
                                         measure=measure)
    assert best.group_t == 2
    assert rows[0]["time_us"] <= rows[-1]["time_us"]
    key = tuning.cache_key("testchip", jnp.float32, 64, 4, 4, m=8)
    assert cache.get(key).group_t == 2
    cache.save()
    assert tuning.TuningCache.load(cache.path).get(key).group_t == 2


def test_autotune_batched_real_measure_interpret(tmp_path):
    """End-to-end group sweep through the actual megakernel in interpret
    mode (what the CI autotune smoke runs)."""
    cache = tuning.TuningCache.load(tmp_path / "c.json")
    best, rows = tuning.autotune_batched(4, 48, 3, 4, cache=cache,
                                         repeats=1, interpret=True,
                                         group_ts=(1, 2))
    assert best is not None and best.group_t in {r["group_t"] for r in rows}
    assert cache.entries
