"""Data pipeline: determinism, resume purity, shard layout."""
import numpy as np
import pytest

from repro.data.pipeline import PipelineConfig, TokenPipeline


def test_batches_are_deterministic():
    cfg = PipelineConfig(vocab_size=1000, global_batch=4, seq_len=8, seed=1)
    a = TokenPipeline(cfg).batch(12)
    b = TokenPipeline(cfg).batch(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ():
    cfg = PipelineConfig(vocab_size=1000, global_batch=4, seq_len=8, seed=1)
    p = TokenPipeline(cfg)
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = PipelineConfig(vocab_size=1000, global_batch=2, seq_len=16, seed=0)
    b = TokenPipeline(cfg).batch(3)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_resume_no_replay_needed():
    """batch(step) is pure: restoring at step k needs no stream replay."""
    cfg = PipelineConfig(vocab_size=50, global_batch=2, seq_len=4, seed=9)
    fresh = TokenPipeline(cfg)
    replayed = TokenPipeline(cfg)
    for s in range(5):
        replayed.batch(s)
    np.testing.assert_array_equal(fresh.batch(5)["tokens"],
                                  replayed.batch(5)["tokens"])


def test_host_shards_disjoint_and_deterministic():
    cfg = PipelineConfig(vocab_size=10**6, global_batch=8, seq_len=6, seed=2)
    shards = [TokenPipeline(cfg).reshard(4, h).batch(1)["tokens"]
              for h in range(4)]
    rows = [tuple(r) for s in shards for r in s.tolist()]
    assert len(set(rows)) == len(rows)
    again = TokenPipeline(cfg).reshard(4, 2).batch(1)["tokens"]
    np.testing.assert_array_equal(shards[2], again)


def test_bad_host_split_rejected():
    cfg = PipelineConfig(vocab_size=10, global_batch=7, seq_len=2,
                         num_hosts=2)
    with pytest.raises(ValueError):
        TokenPipeline(cfg).batch(0)
