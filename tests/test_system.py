"""End-to-end behaviour tests: the paper's pipeline + the LM stack together."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.core import IPKMeansConfig, ipkmeans, pkmeans
from repro.core.kmeans import KMeansParams
from repro.data import gaussian_mixture, initial_centroid_groups


def test_paper_pipeline_end_to_end():
    """Full IPKMeans run on paper-style data recovers the planted clusters
    about as well as PKMeans does.

    With ~500-point subsets a centroid that captures no points would stay
    frozen at its init in every reducer (empty-cluster keep-old semantics)
    and all reducers would converge to the same poor local minimum;
    ``reseed_empty`` re-seeds those centroids at the farthest in-subset
    point, which closes the gap (the ROADMAP open item this test gated)."""
    pts, centers, _ = gaussian_mixture(jax.random.key(42), 3000, 5)
    init = initial_centroid_groups(pts, 5, groups=1)[0]
    ref = pkmeans(pts, init)
    res = ipkmeans(pts, init, jax.random.key(0),
                   IPKMeansConfig(num_clusters=5, num_subsets=6,
                                  kmeans=KMeansParams(reseed_empty=True)))
    assert float(res.sse) <= float(ref.sse) * 1.05
    # every recovered centroid is near a planted center (clusters overlap
    # with sigma=2, so 'near' is within ~1 sigma)
    d = np.asarray(jnp.linalg.norm(
        res.centroids[:, None, :] - centers[None], axis=-1).min(axis=1))
    assert (d < 2.5).all(), d


def test_lm_training_reduces_loss():
    """A few steps on a tiny LM: loss moves down (the end-to-end driver in
    examples/train_lm.py runs the longer version).

    The synthetic corpus is uniform-random tokens, so fresh batches carry no
    learnable signal and the loss delta across them is noise; the smoke
    overfits ONE fixed batch (warmup-free schedule, no weight decay), where
    the decrease is systematic."""
    from repro import optim
    from repro.data.pipeline import PipelineConfig, TokenPipeline
    from repro.launch.train import make_train_step
    from repro.models import registry
    cfg = SMOKE_ARCHS["minicpm-2b"]
    pipe = TokenPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                        global_batch=4, seq_len=32))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    params = registry.init_params(jax.random.key(0), cfg)
    adamw_cfg = optim.AdamWConfig(weight_decay=0.0)
    opt_state = optim.init(params, adamw_cfg)
    step_fn = jax.jit(make_train_step(cfg, adamw_cfg,
                                      schedule=lambda step: 1e-3))
    losses = []
    for step in range(10):
        params, opt_state, m = step_fn(params, opt_state, batch, step)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_greedy_generation_runs():
    from repro.launch.serve import greedy_generate
    from repro.models import registry
    cfg = SMOKE_ARCHS["mixtral-8x7b"]
    params = registry.init_params(jax.random.key(0), cfg)
    prompts = jax.random.randint(jax.random.key(1), (2, 4), 0,
                                 cfg.vocab_size)
    out = greedy_generate(cfg, params, prompts, max_new=4)
    assert out.shape == (2, 8)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()


def test_vq_codebook_via_ipkmeans():
    """The chameleon touchpoint: train a VQ codebook over synthetic patch
    embeddings with IPKMeans and check quantization error ~ PKMeans's.
    High-d codebooks need representative subsets: 4 reducers x 512 points."""
    embeds, _, _ = gaussian_mixture(jax.random.key(7), 2048, 16, d=8)
    init = initial_centroid_groups(embeds, 16, groups=1)[0]
    ref = pkmeans(embeds, init)
    res = ipkmeans(embeds, init, jax.random.key(0),
                   IPKMeansConfig(num_clusters=16, num_subsets=4))
    assert float(res.sse) <= float(ref.sse) * 1.15
