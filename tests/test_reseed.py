"""In-kernel empty-cluster reseeding: the megakernels stay on the paper's
hot path with ``reseed_empty=True``.

The contract under test: the resident and batched-resident kernels fold the
farthest-point reseed into their on-chip convergence loops, and the result is
bit-for-bit the host-side ``engine.reseed_empty_clusters`` oracle path (the
old fused-fallback loop) — both run the SAME ``ref.reseed_farthest``
selection, so parity rests on shared code.  Plus the nasty corners: the
all-padding subset, an every-cluster-empty lane, reseed firing on the final
iteration, more clusters than points, and bf16 carries.  All in interpret
mode (the CI kernel gate).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import KMeansParams, kmeans, kmeans_batched
from repro.kernels import ops, ref, resident
from repro.kernels import engine as engines


def _data(n, d, k, dtype=jnp.float32, scale=3.0, seed=1):
    kx, kc = jax.random.split(jax.random.key(n * d * k + seed))
    x = (jax.random.normal(kx, (n, d)) * scale).astype(dtype)
    c = (jax.random.normal(kc, (k, d)) * scale).astype(dtype)
    return x, c


def _far_init(d, k, dtype=jnp.float32):
    """Init centroids planted far outside the data so early iterations
    reliably produce empty clusters (the reseed trigger)."""
    return (jax.random.normal(jax.random.key(99), (k, d)) * 5
            + 100.0).astype(dtype)


def _assert_results_equal(a, b):
    for field, va, vb in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(va, np.float32) if va.dtype == jnp.bfloat16 else
            np.asarray(va),
            np.asarray(vb, np.float32) if vb.dtype == jnp.bfloat16 else
            np.asarray(vb),
            err_msg=field)


# ------------------------------------------ the shared selection function --

def _reseed_topk_reference(points, score, empty, kk):
    """The pre-refactor host implementation (lax.top_k + gather), kept here
    as an independent oracle for the shared masked-argmax selection: the
    e-th empty cluster takes the e-th farthest point, slots are consumed
    positionally, exhausted/infinite slots keep the old centroid."""
    vals, far = jax.lax.top_k(score, kk)
    picks = points[far]
    raw = jnp.cumsum(empty.astype(jnp.int32)) - 1
    slot = jnp.clip(raw, 0, kk - 1)
    ok = jnp.logical_and(raw < kk, jnp.isfinite(vals[slot]))
    return empty & ok, picks[slot]


@pytest.mark.parametrize("n,k,n_empty", [(16, 4, 2), (8, 12, 9), (6, 6, 6)])
def test_reseed_farthest_matches_topk_reference(n, k, n_empty):
    """``ref.reseed_farthest`` (the kernel-traceable masked-argmax chain)
    is bit-for-bit the top_k formulation, including multiple empties taking
    DISTINCT points in farthest-first order."""
    d = 3
    points = jax.random.normal(jax.random.key(n * k), (n, d))
    score = jax.random.uniform(jax.random.key(7), (n,))
    empty = jnp.zeros((k,), bool).at[jnp.arange(n_empty)].set(True)
    kk = min(n, k)
    take, picks = ref.reseed_farthest(points, score, empty, kk)
    take_r, picks_r = _reseed_topk_reference(points, score, empty, kk)
    np.testing.assert_array_equal(np.asarray(take), np.asarray(take_r))
    # non-taken rows are caller's responsibility; compare the taken picks
    np.testing.assert_array_equal(np.asarray(picks)[np.asarray(take)],
                                  np.asarray(picks_r)[np.asarray(take_r)])


def test_reseed_farthest_tie_break_and_exhaustion():
    """Equal scores break to the lowest point index (lax.top_k's stable
    order), and empties past the candidate budget keep the old centroid."""
    points = jnp.arange(8.0)[:, None] * jnp.ones((1, 2))
    score = jnp.array([5.0, 5.0, 5.0, -jnp.inf, 1.0,
                       -jnp.inf, -jnp.inf, -jnp.inf])
    empty = jnp.array([True] * 5)
    take, picks = ref.reseed_farthest(points, score, empty, kk=5)
    # picks 0,1,2 (ties, index order), then 4 (score 1.0), then exhausted
    np.testing.assert_array_equal(np.asarray(take),
                                  [True, True, True, True, False])
    np.testing.assert_array_equal(np.asarray(picks[:4, 0]), [0.0, 1.0, 2.0, 4.0])


def test_reseed_farthest_property_vs_topk():
    """hypothesis sweep: random scores (with forced ties and -inf rows) and
    random empty sets — shared selection vs the top_k oracle, bit-for-bit."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the 'dev' extra (pip install -e '.[dev]')")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(4, 24), st.integers(2, 10), st.integers(0, 2 ** 31 - 1),
           st.booleans())
    @settings(max_examples=25, deadline=None)
    def prop(n, k, seed, quantize):
        kq, ke, kv = jax.random.split(jax.random.key(seed), 3)
        score = jax.random.uniform(kq, (n,)) * 10
        if quantize:                       # integer scores force ties
            score = jnp.floor(score)
        score = jnp.where(jax.random.uniform(kv, (n,)) < 0.25,
                          -jnp.inf, score)
        empty = jax.random.uniform(ke, (k,)) < 0.5
        points = jax.random.normal(kv, (n, 3))
        kk = min(n, k)
        take, picks = ref.reseed_farthest(points, score, empty, kk)
        take_r, picks_r = _reseed_topk_reference(points, score, empty, kk)
        np.testing.assert_array_equal(np.asarray(take), np.asarray(take_r))
        np.testing.assert_array_equal(np.asarray(picks)[np.asarray(take)],
                                      np.asarray(picks_r)[np.asarray(take_r)])

    prop()


# ------------------------------------- in-kernel vs the host-side oracle --

def _assert_solve_matches_oracle(got, want):
    """Kernel solve vs host-oracle solve: centroids, iteration count and
    converged flag are bit-for-bit (the reseed picks are exact point copies
    and divide_or_keep is shared code); the final scalar SSE is a global
    (n,) -> () reduction whose tree shape depends on the padded length, so
    the kernel (n_pad) and the fused host path (block_n tile) may differ in
    the last ulp — allow exactly that, nothing more."""
    c_g, sse_g, it_g, conv_g = got
    c_w, sse_w, it_w, conv_w = want
    np.testing.assert_array_equal(np.asarray(c_g), np.asarray(c_w))
    np.testing.assert_array_equal(np.asarray(it_g), np.asarray(it_w))
    np.testing.assert_array_equal(np.asarray(conv_g), np.asarray(conv_w))
    np.testing.assert_allclose(np.asarray(sse_g), np.asarray(sse_w),
                               rtol=1e-6)


def _host_loop_solve(points, init, w, *, max_iters, tol):
    """The old fallback: the generic host-side while_loop over the fused
    engine's step/assign with per-iteration ``reseed_empty_clusters`` — what
    ``resident``/``batched`` used to drop to whenever reseeding was on."""
    eng = engines.get_engine("fused")
    return engines.LloydEngine.solve(eng, points, init, w,
                                     max_iters=max_iters, tol=tol,
                                     reseed_empty=True)


@pytest.mark.parametrize("n,d,k", [(60, 2, 3), (48, 5, 8), (33, 3, 6)])
@pytest.mark.parametrize("masked", [False, True])
def test_resident_reseed_matches_host_oracle(n, d, k, masked):
    """The in-kernel reseed is bit-for-bit the host-side
    ``reseed_empty_clusters`` oracle loop through the whole solve."""
    x, _ = _data(n, d, k)
    init = _far_init(d, k)                      # guarantees empty clusters
    w = None
    if masked:
        w = (jax.random.uniform(jax.random.key(5), (n,)) > 0.25).astype(
            jnp.float32)
    got = ops.lloyd_solve_resident(x, init, w, max_iters=25, tol=1e-6,
                                   reseed_empty=True)
    want = _host_loop_solve(x, init, w, max_iters=25, tol=1e-6)
    _assert_solve_matches_oracle(got, want)
    # the far-planted centroids actually moved (reseed fired, not a no-op)
    assert float(jnp.abs(got[0]).max()) < 60.0


def test_reseed_property_in_kernel_vs_host_oracle():
    """hypothesis sweep: random subsets/shapes/masks — resident-kernel and
    batched-megakernel reseed vs the host-side oracle loop, bit-for-bit on
    every engine's whole KMeansResult."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the 'dev' extra (pip install -e '.[dev]')")
    from hypothesis import given, settings, strategies as st

    @given(st.sampled_from([(40, 2, 5), (32, 3, 8), (24, 4, 4)]),
           st.booleans(), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=8, deadline=None)
    def prop(shape, masked, seed):
        n, d, k = shape
        x, _ = _data(n, d, k, seed=seed % 1000)
        init = _far_init(d, k)
        w = None
        if masked:
            w = (jax.random.uniform(jax.random.key(seed % 997), (n,))
                 > 0.3).astype(jnp.float32)
        want = _host_loop_solve(x, init, w, max_iters=15, tol=1e-6)
        got_res = ops.lloyd_solve_resident(x, init, w, max_iters=15,
                                           tol=1e-6, reseed_empty=True)
        got_bat = ops.lloyd_solve_batched(x[None], init, None if w is None
                                          else w[None], group_t=1,
                                          max_iters=15, tol=1e-6,
                                          reseed_empty=True)
        _assert_solve_matches_oracle(got_res, want)
        # batched lane 0 vs the single-subset kernel: fully bitwise
        for g, b in zip(got_res, got_bat):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(b[0]))

    prop()


def test_batched_reseed_matches_vmap_resident_bitwise():
    """backend='batched' == backend='resident' with reseed on — bit-for-bit
    through the stacked KMeansResult, groups mixing lanes with and without
    empty clusters."""
    m, s, d, k = 5, 40, 3, 6
    x, _ = _data(s * m, d, k)
    x = x.reshape(m, s, d)
    # lane 0 clusters normally; the far init empties clusters in every lane
    masks = jnp.ones((m, s), bool).at[3, 20:].set(False)
    init = _far_init(d, k)
    p = KMeansParams(max_iters=20, reseed_empty=True)
    r_bat = kmeans_batched(x, masks, init, p._replace(backend="batched"))
    r_vm = kmeans_batched(x, masks, init, p._replace(backend="resident"))
    _assert_results_equal(r_bat, r_vm)
    # reseed actually fired: no centroid left stranded at the far init
    assert float(jnp.abs(r_bat.centroids).max()) < 60.0


# ----------------------------------------------------------- nasty corners --

def test_all_padding_subset_with_reseed():
    """An all-padding lane has every cluster empty AND every score -inf:
    reseed must keep the old centroids (never leak padding coordinates),
    converge on trip 1, and report sse 0 / ASSE +inf."""
    m, s, d, k = 3, 16, 2, 4
    x, _ = _data(s * m, d, k)
    x = x.reshape(m, s, d)
    masks = jnp.ones((m, s), bool).at[1].set(False)
    init = _far_init(d, k)
    p = KMeansParams(max_iters=10, reseed_empty=True)
    r_bat = kmeans_batched(x, masks, init, p._replace(backend="batched"))
    r_vm = kmeans_batched(x, masks, init, p._replace(backend="resident"))
    _assert_results_equal(r_bat, r_vm)
    np.testing.assert_array_equal(np.asarray(r_bat.centroids[1]),
                                  np.asarray(init))
    assert float(r_bat.sse[1]) == 0.0 and np.isinf(float(r_bat.asse[1]))
    assert int(r_bat.iters[1]) == 1 and bool(r_bat.converged[1])


def test_more_empty_clusters_than_points():
    """k > n valid points: nearest-centroid assignment populates p >= 1
    clusters and leaves k - p empty, but only kk = n candidate points exist
    — min(k - p, n) empties reseed onto distinct points, the rest keep the
    old (far) centroid.  Kernel vs host oracle, bit-for-bit."""
    n, d, k = 5, 2, 9
    x = jax.random.normal(jax.random.key(3), (n, d))
    init = _far_init(d, k)
    got = ops.lloyd_solve_resident(x, init, max_iters=8, tol=1e-6,
                                   reseed_empty=True)
    want = _host_loop_solve(x, init, None, max_iters=8, tol=1e-6)
    _assert_solve_matches_oracle(got, want)
    # after the FIRST iteration: the p populated clusters moved to their
    # point means and exactly min(k - p, n) empties were served a pick —
    # the candidate pool is exhausted after n, so the rest stay far
    labels, _ = ref.assign_ref(x, init)
    p = len(np.unique(np.asarray(labels)))
    served = min(k - p, n)
    first = ops.lloyd_solve_resident(x, init, max_iters=1, tol=1e-6,
                                     reseed_empty=True)
    far = np.abs(np.asarray(first[0])).max(axis=1) > 60.0
    assert (~far).sum() == p + served and far.sum() == k - p - served
    # served picks are EXACT copies of in-subset points (a populated
    # singleton cluster's mean may coincide with its point too, hence >=)
    exact = sum(any(np.array_equal(row, pt) for pt in np.asarray(x))
                for row in np.asarray(first[0]))
    assert exact >= served


def test_reseed_fires_on_final_iteration():
    """max_iters=1 with a guaranteed-empty init: the reseed lands on the
    LAST trip and the final statistics pass must score the reseeded
    centroids — identical between kernel and host loop."""
    n, d, k = 30, 2, 4
    x, _ = _data(n, d, k)
    init = _far_init(d, k)
    got = ops.lloyd_solve_resident(x, init, max_iters=1, tol=1e-6,
                                   reseed_empty=True)
    want = _host_loop_solve(x, init, None, max_iters=1, tol=1e-6)
    _assert_solve_matches_oracle(got, want)
    assert int(got[2]) == 1
    # the reseeded rows are exact in-subset points, not averages
    moved = np.abs(np.asarray(got[0])).max(axis=1) < 60.0
    assert moved.any()


def test_bf16_carry_reseed_roundtrip():
    """bf16 stacks: picks round-trip the carry dtype exactly like centroid
    updates, so batched and vmap-of-resident stay bit-for-bit in bf16."""
    m, s, d, k = 4, 32, 4, 5
    x, _ = _data(s * m, d, k, dtype=jnp.bfloat16)
    x = x.reshape(m, s, d)
    masks = jnp.ones((m, s), bool).at[2, 20:].set(False)
    init = _far_init(d, k, dtype=jnp.bfloat16)
    p = KMeansParams(max_iters=12, reseed_empty=True)
    r_bat = kmeans_batched(x, masks, init, p._replace(backend="batched"))
    r_vm = kmeans_batched(x, masks, init, p._replace(backend="resident"))
    assert r_bat.centroids.dtype == jnp.bfloat16
    _assert_results_equal(r_bat, r_vm)


# ----------------------------------------- engines stay on their kernels --

def test_resident_engine_keeps_kernel_with_reseed(monkeypatch):
    """reseed_empty=True must NOT push the resident engine onto the host
    fused loop anymore — the kernel launches exactly once per solve."""
    calls = {"resident": 0}
    real = ops.lloyd_solve_resident

    def counting(*args, **kwargs):
        calls["resident"] += 1
        assert kwargs.get("reseed_empty") is True
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lloyd_solve_resident", counting)
    x, _ = _data(64, 2, 4)
    engines.get_engine("resident").solve(x, _far_init(2, 4), max_iters=6,
                                         tol=1e-6, reseed_empty=True)
    assert calls["resident"] == 1


def test_resident_engine_still_falls_back_when_infeasible(monkeypatch):
    """The ONLY remaining fallback is a genuinely infeasible shape — and it
    still honors reseed_empty through the host loop."""
    def boom(*args, **kwargs):
        raise AssertionError("resident kernel launched on infeasible shape")

    monkeypatch.setattr(ops, "lloyd_solve_resident", boom)
    monkeypatch.setattr(resident, "resident_feasible",
                        lambda n, d, k, budget=None, prune="none": False)
    x, _ = _data(64, 2, 3)
    init = jnp.array([[0.0, 0.0], [0.5, 0.5], [500.0, 500.0]])
    c, _, _, _ = engines.get_engine("resident").solve(
        x, init, max_iters=15, tol=1e-6, reseed_empty=True)
    assert float(jnp.abs(c[2]).max()) < 50.0          # reseed still rescued it


def test_tuned_engine_keeps_kernel_and_cache_with_reseed(monkeypatch,
                                                         tmp_path):
    """`tuned` + reseed_empty: the solve stays on the resident kernel and
    the batched stack path still resolves group_t from the autotuning cache
    instead of dropping to the fallback (the old ``t=0`` short-circuit)."""
    from repro.kernels import specs, tuning

    x, _ = _data(64, 2, 4)
    calls = {"resident": 0}
    real = ops.lloyd_solve_resident

    def counting(*args, **kwargs):
        calls["resident"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lloyd_solve_resident", counting)
    engines.get_engine("tuned").solve(x, _far_init(2, 4), max_iters=5,
                                      tol=1e-6, reseed_empty=True)
    assert calls["resident"] == 1

    # batched stack: seed a cached group_t winner and watch it reach the
    # kernel launch with reseed on
    m, s, d, k = 6, 32, 3, 4
    path = tmp_path / "kernel_specs.json"
    cache = tuning.TuningCache.load(path)
    kind = specs.get_profile().device_kind
    cache.put(tuning.cache_key(kind, jnp.float32, s, d, k, m=m),
              specs.DEFAULT_SPEC.replace(group_t=3))
    cache.save()
    monkeypatch.setenv(tuning.ENV_CACHE_PATH, str(path))
    tuning.reload_cache()

    seen = {}
    real_b = ops.lloyd_solve_batched

    def spy(*args, **kwargs):
        seen["group_t"] = kwargs.get("group_t")
        seen["reseed_empty"] = kwargs.get("reseed_empty")
        return real_b(*args, **kwargs)

    monkeypatch.setattr(ops, "lloyd_solve_batched", spy)
    xs, _ = _data(s * m, d, k)
    engines.get_engine("batched").solve_batched(
        xs.reshape(m, s, d), _far_init(d, k), max_iters=5, tol=1e-6,
        reseed_empty=True)
    assert seen == {"group_t": 3, "reseed_empty": True}
    tuning.reload_cache()
