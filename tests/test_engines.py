"""LloydEngine registry: cross-engine parity, the resident solver vs the jnp
oracle, the VMEM-feasibility fallback, and empty-cluster reseeding — all in
interpret mode (the CI kernel gate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import KMeansParams, kmeans
from repro.kernels import engine as engines
from repro.kernels import ops, ref, resident


def _data(n, d, k, dtype=jnp.float32, scale=3.0, seed=1):
    kx, kc = jax.random.split(jax.random.key(n * d * k + seed))
    x = (jax.random.normal(kx, (n, d)) * scale).astype(dtype)
    c = (jax.random.normal(kc, (k, d)) * scale).astype(dtype)
    return x, c


# ---------------------------------------------------------------- registry --

def test_registry_contents():
    assert set(engines.available()) >= {"jnp", "pallas", "fused",
                                        "resident", "tuned"}
    for name in engines.available():
        assert engines.get_engine(name).name == name


def test_registry_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        engines.get_engine("fussed")


def test_registry_accepts_new_engine():
    """The registry is open: a custom engine slots into the same lookup the
    solvers use (the autotuning path future PRs need)."""
    class Echo(engines.LloydEngine):
        name = "_echo_test"
        def step(self, points, centroids, weights=None):
            return ref.lloyd_step_ref(points, centroids, weights)
    engines.register(Echo())
    try:
        assert "_echo_test" in engines.available()
        x, c = _data(64, 2, 3)
        s, cnt, sse = engines.get_engine("_echo_test").step(x, c)
        s_r, cnt_r, sse_r = ref.lloyd_step_ref(x, c)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r))
    finally:
        engines._REGISTRY.pop("_echo_test", None)


# ------------------------------------------------- cross-engine step parity --

ENGINE_NAMES = ("jnp", "pallas", "fused", "resident", "tuned")


def _step_parity_case(n, d, k, dtype, masked, seed):
    x, c = _data(n, d, k, dtype, seed=seed)
    w = None
    if masked:
        w = (jax.random.uniform(jax.random.key(seed), (n,)) > 0.3).astype(
            jnp.float32)
    s_r, cnt_r, sse_r = ref.lloyd_step_ref(x, c, w)
    tol = 1e-3 if dtype == jnp.float32 else 0.2
    for name in ENGINE_NAMES:
        s, cnt, sse = engines.get_engine(name).step(x, c, w)
        np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt_r),
                                   rtol=1e-6, err_msg=name)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                                   rtol=tol, atol=tol, err_msg=name)
        np.testing.assert_allclose(float(sse), float(sse_r), rtol=tol,
                                   err_msg=name)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_engines_step_parity_fixed_shapes(dtype):
    """All registered engines agree with the oracle on (sums, counts, sse),
    with and without masks."""
    _step_parity_case(300, 2, 5, dtype, masked=False, seed=3)
    _step_parity_case(257, 17, 7, dtype, masked=True, seed=4)


def test_engines_step_parity_property():
    """hypothesis sweep: random shapes/masks/dtypes, every engine vs oracle.

    Shapes are drawn from small fixed menus so the jit cache is shared
    across examples (interpret-mode Pallas recompiles per shape)."""
    hyp = pytest.importorskip(
        "hypothesis",
        reason="property tests need the 'dev' extra (pip install -e '.[dev]')")
    from hypothesis import given, settings, strategies as st

    @given(st.sampled_from([(48, 2, 3), (64, 5, 4), (96, 3, 8)]),
           st.sampled_from([jnp.float32, jnp.bfloat16]),
           st.booleans(), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=12, deadline=None)
    def prop(shape, dtype, masked, seed):
        n, d, k = shape
        _step_parity_case(n, d, k, dtype, masked, seed)

    prop()


# --------------------------------------------------- resident solve parity --

@pytest.mark.parametrize("n,d,k", [(300, 2, 5), (512, 6, 8), (257, 17, 7)])
@pytest.mark.parametrize("masked", [False, True])
def test_resident_solve_matches_oracle(n, d, k, masked):
    """The on-chip convergence loop reproduces the jnp solve oracle exactly:
    converged centroids, SSE, iteration count, converged flag."""
    x, _ = _data(n, d, k)
    init = x[:k]
    w = None
    if masked:
        w = (jax.random.uniform(jax.random.key(7), (n,)) > 0.2).astype(
            jnp.float32)
    assert resident.resident_feasible(n, d, k)
    c_r, sse_r, it_r, conv_r = ref.lloyd_solve_ref(x, init, w,
                                                   max_iters=50, tol=1e-6)
    c_p, sse_p, it_p, conv_p = ops.lloyd_solve_resident(x, init, w,
                                                        max_iters=50,
                                                        tol=1e-6,
                                                        interpret=True)
    assert int(it_r) == int(it_p)
    assert bool(conv_r) == bool(conv_p)
    # early convergence must actually exercise the while_loop's exit branch
    assert int(it_p) < 50
    np.testing.assert_allclose(np.asarray(c_r), np.asarray(c_p),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(sse_r), float(sse_p), rtol=1e-5)


def test_resident_solve_hits_max_iters():
    """tol=0 can never be met, so the loop must stop at max_iters with
    converged=False."""
    x, _ = _data(300, 3, 4)
    _, _, it, conv = ops.lloyd_solve_resident(x, x[:4], max_iters=3,
                                              tol=0.0, interpret=True)
    assert int(it) == 3 and not bool(conv)


def test_kmeans_solver_resident_backend():
    """Lloyd-to-convergence with backend='resident' tracks the jnp solver
    through the full KMeansResult (the whole-solve delegation path)."""
    x, _ = _data(512, 6, 8)
    init = x[:8]
    r_jnp = kmeans(x, init, params=KMeansParams(max_iters=25))
    r_res = kmeans(x, init, params=KMeansParams(max_iters=25,
                                                backend="resident"))
    assert int(r_jnp.iters) == int(r_res.iters)
    assert bool(r_jnp.converged) == bool(r_res.converged)
    np.testing.assert_allclose(np.asarray(r_jnp.centroids),
                               np.asarray(r_res.centroids),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(r_jnp.sse), float(r_res.sse), rtol=1e-4)
    np.testing.assert_allclose(float(r_jnp.asse), float(r_res.asse),
                               rtol=1e-4)


# ------------------------------------------------------ feasibility + fall --

def test_resident_feasibility_model():
    from repro.kernels import specs
    assert resident.resident_feasible(300, 2, 5)
    # (n, k) score matrix alone blows the budget — which now comes from the
    # local chip's DeviceProfile (12 MiB conservative default on this host)
    assert not resident.resident_feasible(4096, 8, 2048)
    assert resident.resident_vmem_bytes(4096, 8, 2048) \
        > specs.get_profile().budget_bytes
    # max_resident_points inverts the byte model exactly (S2 sizing knob)
    for d, k in [(2, 5), (16, 64), (64, 1024)]:
        n_max = resident.max_resident_points(d, k)
        assert resident.resident_feasible(n_max, d, k)
        assert not resident.resident_feasible(n_max + 8, d, k)


def test_resident_engine_uses_kernel_when_feasible(monkeypatch):
    calls = {"resident": 0}
    real = ops.lloyd_solve_resident

    def counting(*args, **kwargs):
        calls["resident"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lloyd_solve_resident", counting)
    x, _ = _data(256, 4, 4)
    engines.get_engine("resident").solve(x, x[:4], max_iters=5, tol=1e-6)
    assert calls["resident"] == 1


def test_resident_solve_bf16_matches_fallback(monkeypatch):
    """The kernel path and the fused fallback must produce the SAME solve
    for non-f32 carries too: the kernel rounds its centroid carry back to
    the caller's dtype every iteration exactly like the host loop, so two
    S2 subsets straddling the feasibility boundary never get systematically
    different solvers."""
    x, _ = _data(256, 8, 6, dtype=jnp.bfloat16)
    init = x[:6]
    eng = engines.get_engine("resident")
    c_k, sse_k, it_k, conv_k = eng.solve(x, init, max_iters=30, tol=1e-3)
    monkeypatch.setattr(resident, "resident_feasible",
                        lambda n, d, k, budget=None, prune="none": False)
    c_f, sse_f, it_f, conv_f = eng.solve(x, init, max_iters=30, tol=1e-3)
    assert int(it_k) == int(it_f)
    assert bool(conv_k) == bool(conv_f)
    np.testing.assert_allclose(np.asarray(c_k, np.float32),
                               np.asarray(c_f, np.float32),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(float(sse_k), float(sse_f), rtol=1e-2)


def test_resident_engine_falls_back_when_infeasible(monkeypatch):
    """When (n, d, k) does not fit VMEM the engine must route the solve
    through the fused per-step loop — and still match the jnp solver."""
    def boom(*args, **kwargs):
        raise AssertionError("resident kernel launched on infeasible shape")

    monkeypatch.setattr(ops, "lloyd_solve_resident", boom)
    monkeypatch.setattr(resident, "resident_feasible",
                        lambda n, d, k, budget=None, prune="none": False)
    x, _ = _data(256, 4, 4)
    init = x[:4]
    c_f, sse_f, it_f, conv_f = engines.get_engine("resident").solve(
        x, init, max_iters=10, tol=1e-6)
    c_r, sse_r, it_r, conv_r = ref.lloyd_solve_ref(x, init, max_iters=10,
                                                   tol=1e-6)
    assert int(it_f) == int(it_r)
    np.testing.assert_allclose(np.asarray(c_f), np.asarray(c_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(sse_f), float(sse_r), rtol=1e-4)


# -------------------------------------------------------- fused labels out --

@pytest.mark.parametrize("n,d,k", [(300, 2, 5), (513, 64, 130)])
def test_fused_labels_output_matches_assign(n, d, k):
    """The fused kernel's final-pass labels output == the dedicated assign
    path (same argmin, one sweep instead of two kernels)."""
    x, c = _data(n, d, k)
    labels, mind = ops.lloyd_assign_fused(x, c, interpret=True)
    l_ref, m_ref = ref.assign_ref(x, c)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(l_ref))
    np.testing.assert_allclose(np.asarray(mind), np.asarray(m_ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- reseeding ---

@pytest.mark.parametrize("backend", ENGINE_NAMES)
def test_reseed_empty_rescues_frozen_centroid(backend):
    """A centroid planted unreachably far away captures nothing; with
    reseed_empty it must move onto a real point (the farthest one) and the
    final SSE must beat keep-old-centroid semantics — on every engine."""
    pts = jnp.concatenate([
        jax.random.normal(jax.random.key(0), (60, 2)),
        jax.random.normal(jax.random.key(1), (60, 2)) + 10.0])
    init = jnp.array([[0.0, 0.0], [0.5, 0.5], [500.0, 500.0]])
    frozen = kmeans(pts, init, params=KMeansParams(
        max_iters=20, backend=backend))
    reseeded = kmeans(pts, init, params=KMeansParams(
        max_iters=20, backend=backend, reseed_empty=True))
    # keep-old leaves the far centroid frozen; reseed pulls it into the data
    np.testing.assert_allclose(np.asarray(frozen.centroids[2]),
                               [500.0, 500.0], rtol=1e-5)
    assert float(jnp.abs(reseeded.centroids[2]).max()) < 50.0
    assert float(reseeded.sse) < float(frozen.sse) * 0.9


def test_reseed_never_picks_masked_points():
    """More empty clusters than valid points: top_k falls through to masked
    rows — those slots must keep their old centroid, never leak padding
    coordinates into the output."""
    pts = jnp.concatenate([jnp.zeros((1, 2)),              # one valid point
                           jnp.full((5, 2), 7.0)])         # padding rows
    mask = jnp.array([True] + [False] * 5)
    init = jnp.array([[0.0, 0.0], [50.0, 50.0],
                      [60.0, 60.0], [70.0, 70.0]])
    res = kmeans(pts, init, mask=mask,
                 params=KMeansParams(max_iters=5, reseed_empty=True))
    c = np.asarray(res.centroids)
    assert not np.isclose(c, 7.0).all(axis=1).any(), c
    # the single valid point may claim one empty slot; the rest keep-old
    np.testing.assert_allclose(c[2:], np.asarray(init[2:]), rtol=1e-6)


def test_reseed_empty_in_pkmeans():
    """The global PKMeans solver honors the flag too (single-process path);
    the sharded builder refuses it rather than silently ignoring it."""
    from repro.core.pkmeans import pkmeans, pkmeans_sharded
    pts = jnp.concatenate([
        jax.random.normal(jax.random.key(0), (60, 2)),
        jax.random.normal(jax.random.key(1), (60, 2)) + 10.0])
    init = jnp.array([[0.0, 0.0], [0.5, 0.5], [500.0, 500.0]])
    frozen = pkmeans(pts, init, params=KMeansParams(max_iters=20))
    reseeded = pkmeans(pts, init, params=KMeansParams(max_iters=20,
                                                      reseed_empty=True))
    np.testing.assert_allclose(np.asarray(frozen.centroids[2]),
                               [500.0, 500.0], rtol=1e-5)
    assert float(jnp.abs(reseeded.centroids[2]).max()) < 50.0
    assert float(reseeded.sse) < float(frozen.sse) * 0.9
    with pytest.raises(NotImplementedError, match="reseed_empty"):
        pkmeans_sharded(None, ("data",),
                        KMeansParams(reseed_empty=True))


def test_reseed_empty_noop_when_no_empties():
    """With every cluster populated the flag must not change the solution."""
    x, _ = _data(400, 3, 4)
    base = kmeans(x, x[:4], params=KMeansParams(max_iters=25))
    flagged = kmeans(x, x[:4], params=KMeansParams(max_iters=25,
                                                   reseed_empty=True))
    assert int(base.iters) == int(flagged.iters)
    np.testing.assert_allclose(np.asarray(base.centroids),
                               np.asarray(flagged.centroids), rtol=1e-6)
