"""Attention paths: chunked/windowed/decode vs dense oracle; MoE dispatch
equivalence; recurrent cell equivalences (the fast CI versions of the
development-time sweeps)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, MoEConfig, RecurrentConfig
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models import xlstm as xlstm_lib
from repro.models.attention import (chunked_attention, decode_attention,
                                    dense_attention)


@pytest.fixture(scope="module")
def qkv():
    B, S, H, Hk, Dq, Dv = 2, 257, 8, 2, 32, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dq), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, Dq), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, Dv), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("cq,ck", [(64, 96), (96, 64), (128, 128)])
@pytest.mark.parametrize("window", [None, 48, 200])
def test_chunked_matches_dense(qkv, cq, ck, window):
    q, k, v = qkv
    ref = dense_attention(q, k, v, causal=True, window=window)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=cq, kv_chunk=ck)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


def test_noncausal_matches_dense(qkv):
    q, k, v = qkv
    ref = dense_attention(q, k, v, causal=False)
    out = chunked_attention(q, k, v, causal=False, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 48])
def test_decode_matches_dense_last_row(qkv, window):
    q, k, v = qkv
    B, S = q.shape[:2]
    kvpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = dense_attention(q, k, v, causal=True, window=window)[:, -1:]
    out = decode_attention(q[:, -1:], k, v, kvpos, S - 1, window=window)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


def test_ring_cache_decode():
    """A wrapped ring cache (window smaller than history) still attends to
    exactly the last-window tokens."""
    B, H, Hk, D, W = 1, 4, 2, 16, 8
    ks = jax.random.split(jax.random.key(3), 3)
    S = 20
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    # simulate ring cache at pos = S-1
    ring_k = jnp.zeros((B, W, Hk, D))
    ring_v = jnp.zeros((B, W, Hk, D))
    ring_pos = jnp.full((B, W), -1, jnp.int32)
    for t in range(S):
        slot = t % W
        ring_k = ring_k.at[:, slot].set(k[:, t])
        ring_v = ring_v.at[:, slot].set(v[:, t])
        ring_pos = ring_pos.at[:, slot].set(t)
    ref = dense_attention(q, k, v, causal=True, window=W)[:, -1:]
    out = decode_attention(q[:, -1:], ring_k, ring_v, ring_pos, S - 1,
                           window=W)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-5, atol=2e-5)


def test_moe_dispatch_equivalence():
    d, E, ff = 32, 8, 64
    mcfg = MoEConfig(num_experts=E, top_k=2, d_ff_expert=ff,
                     dispatch="dense", capacity_factor=8.0)
    p = moe_lib.init_moe(jax.random.key(1), d, mcfg, jnp.float32)
    x = jax.random.normal(jax.random.key(0), (2, 16, d))
    ref, _ = moe_lib.moe_ffn(x, p, mcfg)
    for disp in ("gather", "einsum"):
        out, _ = moe_lib.moe_ffn(x, p, dataclasses.replace(mcfg,
                                                           dispatch=disp))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_mla_absorbed_decode_matches_expanded():
    mla = MLAConfig(q_lora_rank=24, kv_lora_rank=16, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    d, H, B, S = 32, 4, 2, 40
    p = mla_lib.init_mla(jax.random.key(0), d, H, mla, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (B, S, d)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = mla_lib.mla_attention(p, x, pos, mla, dense_below=8,
                                 q_chunk=16, kv_chunk=16)
    ckv, kr = mla_lib._latents(p, x, pos, mla, 10_000.0)
    dec = mla_lib.mla_decode(p, x[:, -1:], ckv, kr[:, :, 0, :], pos, S - 1,
                             mla)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_steps():
    rcfg = RecurrentConfig(conv_width=4)
    p = rec_lib.init_recurrent_block(jax.random.key(2), 16, rcfg,
                                     jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, 12, 16)) * 0.5
    y_scan, st_scan = rec_lib.recurrent_block(p, x)
    st = rec_lib.init_state(2, 16, rcfg, jnp.float32)
    outs = []
    for t in range(12):
        o, st = rec_lib.recurrent_block(p, x[:, t:t + 1], st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(y_scan),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [1, 7, 37, 64])
def test_mlstm_chunked_matches_recurrent(chunk):
    B, S, H, dk, dv = 2, 37, 3, 8, 10
    ks = jax.random.split(jax.random.key(0), 5)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2
    fg = jax.random.normal(ks[4], (B, S, H)) * 2 + 2
    h_ref, st_ref = xlstm_lib.mlstm_recurrent(q, k, v, ig, fg)
    h, st = xlstm_lib.mlstm_chunked(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref["n"]), np.asarray(st["n"]),
                               rtol=1e-3, atol=1e-4)
