"""Optimizer + schedules + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.distributed import compress
from repro.optim import schedules


def test_adamw_minimizes_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    state = optim.init(params)
    cfg = optim.AdamWConfig(weight_decay=0.0)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for step in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = optim.update(g, state, params, 0.1, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_state_tracks_f32():
    params = {"x": jnp.full((4,), 2.0)}
    s32 = optim.init(params, optim.AdamWConfig(state_dtype="float32"))
    s16 = optim.init(params, optim.AdamWConfig(state_dtype="bfloat16"))
    p32, p16 = params, params
    for _ in range(50):
        g = {"x": p32["x"] * 0.5}
        p32, s32, _ = optim.update(g, s32, p32, 0.05,
                                   optim.AdamWConfig(state_dtype="float32",
                                                     weight_decay=0.0))
        g = {"x": p16["x"] * 0.5}
        p16, s16, _ = optim.update(g, s16, p16, 0.05,
                                   optim.AdamWConfig(state_dtype="bfloat16",
                                                     weight_decay=0.0))
    np.testing.assert_allclose(np.asarray(p32["x"]), np.asarray(p16["x"]),
                               atol=0.05)
    assert s16.m["x"].dtype == jnp.bfloat16


def test_grad_clip():
    params = {"x": jnp.zeros((3,))}
    state = optim.init(params)
    cfg = optim.AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    _, _, gnorm = optim.update({"x": jnp.full((3,), 100.0)}, state, params,
                               0.1, cfg)
    assert float(gnorm) > 100.0   # reported norm is pre-clip


def test_wsd_schedule_shape():
    lr = lambda s: float(schedules.wsd(s, peak_lr=1.0, warmup=10,
                                       stable=80, decay=10))
    assert lr(0) == 0.0
    assert abs(lr(5) - 0.5) < 1e-6
    assert lr(50) == 1.0                     # stable plateau
    assert lr(89) == 1.0
    assert lr(95) < 0.5                      # decaying
    assert lr(100) <= 0.011


def test_cosine_schedule_monotone_tail():
    vals = [float(schedules.cosine(s, peak_lr=1.0, warmup=5, total=50))
            for s in range(5, 50)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_int8_error_feedback_unbiased():
    """With EF, the *accumulated* quantized stream tracks the true stream."""
    key = jax.random.key(0)
    g_true = jax.random.normal(key, (64,)) * 0.1
    state = compress.init_ef({"g": g_true})
    acc_q = jnp.zeros((64,))
    acc_t = jnp.zeros((64,))
    for i in range(30):
        g = {"g": g_true * (1.0 + 0.1 * i)}
        payload, state = compress.compress_grads(g, state)
        deq = compress.decompress_grads(payload)
        acc_q += deq["g"]
        acc_t += g["g"]
    rel = float(jnp.linalg.norm(acc_q - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.01, rel


def test_int8_payload_is_4x_smaller():
    g = {"g": jnp.zeros((1024,), jnp.float32)}
    payload, _ = compress.compress_grads(g, compress.init_ef(g))
    raw = compress.payload_bytes(g)
    comp = compress.payload_bytes(payload)
    assert comp <= raw / 3.9
