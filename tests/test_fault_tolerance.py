"""Fault-tolerance protocol: failure detection, straggler eviction, elastic
recovery, deterministic resume."""
import numpy as np
import pytest

from repro.distributed.runtime import (Coordinator, FTConfig, RecoveryPlan,
                                       run_with_recovery)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_timeout_eviction():
    clock = FakeClock()
    c = Coordinator(4, FTConfig(heartbeat_timeout=10.0), clock=clock)
    clock.t = 5.0
    for w in (0, 1, 2):
        c.heartbeat(w, step=1, step_time=1.0)
    clock.t = 12.0          # worker 3 silent 12s (> timeout); 0-2 only 7s
    res = c.sweep()
    assert res["evicted"] == [3]
    assert res["reasons"][3] == "heartbeat-timeout"
    assert c.alive_workers() == [0, 1, 2]


def test_straggler_eviction():
    clock = FakeClock()
    c = Coordinator(4, FTConfig(straggler_factor=3.0, straggler_patience=3),
                    clock=clock)
    for step in range(4):
        clock.t += 1.0
        for w in range(4):
            c.heartbeat(w, step, 10.0 if w == 2 else 1.0)
    res = c.sweep()
    assert res["evicted"] == [2]
    assert res["reasons"][2] == "straggler"


def test_min_workers_guard():
    clock = FakeClock()
    c = Coordinator(2, FTConfig(heartbeat_timeout=1.0, min_workers=2),
                    clock=clock)
    clock.t = 5.0
    with pytest.raises(RuntimeError):
        c.sweep()


def test_elastic_rejoin_bumps_generation():
    c = Coordinator(2)
    g0 = c.generation
    c.join(7)
    assert c.generation == g0 + 1
    assert 7 in c.alive_workers()


def test_recovery_resumes_from_checkpoint():
    """Crash at step 7 -> fleet drops worker, restores step-5 checkpoint,
    recomputes 5..10 with fewer data shards, ends at the same global state
    as the data-pipeline purity guarantees."""
    state = {"sum": 0.0, "ckpt": {}, "last_ckpt_step": 0}

    def train_one_step(step, workers):
        # each worker contributes a deterministic shard value: batch(step)
        # is pure, so shard union is identical regardless of worker count
        state["sum"] += sum(step * 1000 + i for i in range(8)) / 8

    def save_fn(step):
        state["ckpt"][step] = state["sum"]
        state["last_ckpt_step"] = step

    def restore_fn():
        step = state["last_ckpt_step"]
        state["sum"] = state["ckpt"].get(step, 0.0)
        return step

    log = run_with_recovery(train_one_step, num_workers=4, steps=10,
                            save_every=5, save_fn=save_fn,
                            restore_fn=restore_fn, fail_at={7: 2})
    events = [e[0] for e in log]
    assert "recover" in events
    rec = [e for e in log if e[0] == "recover"][0]
    assert rec[3] == 5          # restarted from checkpoint step 5
    assert rec[4] == 3          # fleet shrank to 3 data shards
    # final state equals a crash-free run
    expected = sum(s * 1000 + 3.5 for s in range(10))
    assert abs(state["sum"] - expected) < 1e-6


def test_data_pipeline_elastic_reshard():
    """Union of host shards is invariant to host count (what makes elastic
    rescale lossless)."""
    from repro.data.pipeline import PipelineConfig, TokenPipeline
    base = PipelineConfig(vocab_size=100, global_batch=8, seq_len=4,
                          num_hosts=1, host_id=0, seed=3)
    full = TokenPipeline(base).batch(5)["tokens"]
    parts = [TokenPipeline(base).reshard(4, h).batch(5)["tokens"]
             for h in range(4)]
    # every 4-host shard row appears in ... NOTE: resharding changes the
    # random stream per host; the invariant we guarantee is determinism
    # (same (hosts, host_id, step) -> same data) and shard disjointness.
    again = [TokenPipeline(base).reshard(4, h).batch(5)["tokens"]
             for h in range(4)]
    for a, b in zip(parts, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    flat = {tuple(r) for p in parts for r in np.asarray(p).tolist()}
    assert len(flat) == sum(p.shape[0] for p in parts)   # disjoint rows


# ---------------- per-stack recovery (IPKMeans S2) ----------------

def _counter_advance(need):
    """A deterministic stand-in for a Lloyd round: state is an int counter,
    stack s converges once it reaches need[s]."""
    def advance(s, v):
        return v + 1, v + 1 >= need[s]
    return advance


def test_stack_recovery_restores_only_orphan_from_snapshot():
    from repro.distributed.runtime import solve_stacks_with_recovery
    need = [6, 6, 6, 6]
    # 4 stacks / 2 workers; worker 1 crashes at round 3 (after the round-2
    # snapshot), eviction lands once heartbeat_timeout=1.5 elapses
    states, log, work = solve_stacks_with_recovery(
        _counter_advance(need), [0, 0, 0, 0], num_workers=2, max_rounds=30,
        snapshot_every=2, fail_at={3: 1},
        cfg=FTConfig(heartbeat_timeout=1.5, min_workers=1))
    assert states == need                        # every stack completed
    events = [e[0] for e in log]
    assert "crash" in events and "recover" in events
    rec = [e for e in log if e[0] == "recover"][0]
    assert rec[2] == (1,)                        # worker 1 evicted
    assert rec[3] == {1: 1, 3: 1}                # orphans restored from the
    #                                              round-1 snapshot
    # survivors' stacks (0, 2) advanced exactly need times — no recompute;
    # the orphans (1, 3) redo the rounds lost between snapshot and eviction
    per_stack = {s: sum(1 for *_, ss in work if ss == s) for s in range(4)}
    assert per_stack[0] == per_stack[2] == 6
    assert per_stack[1] > 6 and per_stack[3] > 6


def test_stack_recovery_zero_surviving_checkpoints():
    """Crash BEFORE the first snapshot boundary: the orphaned stacks must
    restart from their initial states (restored round -1), not from a
    half-written snapshot."""
    from repro.distributed.runtime import solve_stacks_with_recovery
    need = [4, 4]
    states, log, work = solve_stacks_with_recovery(
        _counter_advance(need), [0, 0], num_workers=2, max_rounds=30,
        snapshot_every=10, fail_at={0: 1},
        cfg=FTConfig(heartbeat_timeout=1.5, min_workers=1))
    assert states == need
    rec = [e for e in log if e[0] == "recover"][0]
    assert rec[3] == {1: -1}                     # no snapshot ever committed
    # stack 1 lost NOTHING it had done (it did nothing before the crash),
    # but restarts from init: total advances == need
    assert sum(1 for *_, s in work if s == 1) == 4


def test_stack_recovery_timeout_during_final_round():
    """The victim crashes on what would have been its LAST round: the
    reassigned owner must still finish the stack from the snapshot rather
    than marking it converged off the dead worker's lost progress."""
    from repro.distributed.runtime import solve_stacks_with_recovery
    need = [3, 5]
    states, log, work = solve_stacks_with_recovery(
        _counter_advance(need), [0, 0], num_workers=2, max_rounds=30,
        snapshot_every=2, fail_at={4: 1},
        cfg=FTConfig(heartbeat_timeout=1.5, min_workers=1))
    assert states == need
    rec = [e for e in log if e[0] == "recover"][0]
    assert rec[2] == (1,)
    # after recovery, stack 1's advances continue under worker 0
    post = [w for rnd, w, s in work if s == 1 and rnd > rec[1]]
    assert post and all(w == 0 for w in post)


def test_stack_recovery_dead_worker_rejoins_after_sweep():
    """A worker evicted by sweep() re-joins later: it must re-enter the
    membership (generation bump), receive stacks at the next plan, and
    actually advance them."""
    from repro.distributed.runtime import solve_stacks_with_recovery
    need = [12] * 4
    states, log, work = solve_stacks_with_recovery(
        _counter_advance(need), [0] * 4, num_workers=2, max_rounds=60,
        snapshot_every=2, fail_at={3: 1}, rejoin_at={8: 1},
        cfg=FTConfig(heartbeat_timeout=1.5, min_workers=1))
    assert states == need
    events = [e[0] for e in log]
    assert events.count("crash") == 1 and events.count("rejoin") == 1
    rejoin_round = [e for e in log if e[0] == "rejoin"][0][1]
    # the rejoined worker does real work after re-entry
    assert any(w == 1 and rnd >= rejoin_round for rnd, w, s in work)


def test_ipkmeans_recoverable_resolves_only_crashed_stack():
    """End to end through the real pipeline: a killed worker's stack
    re-solves from its last centroid snapshot, survivors never recompute,
    and the final result matches the crash-free ipkmeans run exactly."""
    import jax
    from repro.core import IPKMeansConfig, ipkmeans
    from repro.core.ipkmeans import ipkmeans_recoverable
    from repro.data.synthetic import gaussian_mixture
    pts, _, _ = gaussian_mixture(jax.random.PRNGKey(0), 1024, 5, d=2,
                                 spread=8.0, sigma=0.8)
    init = pts[:5]
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=8)
    ref = ipkmeans(pts, init, jax.random.PRNGKey(2), cfg)
    # iters_per_round=2 keeps the solve alive long enough for the crash ->
    # timeout -> eviction sequence (~2.5 rounds) to play out mid-solve
    free, _, work_free = ipkmeans_recoverable(
        pts, init, jax.random.PRNGKey(2), cfg, num_workers=4,
        iters_per_round=2, snapshot_every=2)
    # crash worker 3 (the longest-running stack, still unconverged) ONE
    # round past the round-1 snapshot: that round's live progress dies
    # with the worker, so recovery must actually recompute it
    res, log, work = ipkmeans_recoverable(
        pts, init, jax.random.PRNGKey(2), cfg, num_workers=4,
        iters_per_round=2, snapshot_every=2, fail_at={3: 3})
    # identical solve: chunked Lloyd is Markov in the centroids, and the
    # crashed stack replays from its snapshot to the same fixed point
    np.testing.assert_allclose(np.asarray(res.centroids),
                               np.asarray(ref.centroids), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res.subset_iters),
                                  np.asarray(ref.subset_iters))
    rec = [e for e in log if e[0] == "recover"][0]
    assert rec[2] == (3,)
    # ONLY the crashed worker's stack redid rounds: per-stack advance
    # counts match the crash-free run everywhere except stack 3
    cnt = lambda ws, s: sum(1 for *_, ss in ws if ss == s)
    for s in range(4):
        if s == 3:
            assert cnt(work, s) > cnt(work_free, s)
        else:
            assert cnt(work, s) == cnt(work_free, s)
