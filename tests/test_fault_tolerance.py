"""Fault-tolerance protocol: failure detection, straggler eviction, elastic
recovery, deterministic resume."""
import numpy as np
import pytest

from repro.distributed.runtime import (Coordinator, FTConfig, RecoveryPlan,
                                       run_with_recovery)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_timeout_eviction():
    clock = FakeClock()
    c = Coordinator(4, FTConfig(heartbeat_timeout=10.0), clock=clock)
    clock.t = 5.0
    for w in (0, 1, 2):
        c.heartbeat(w, step=1, step_time=1.0)
    clock.t = 12.0          # worker 3 silent 12s (> timeout); 0-2 only 7s
    res = c.sweep()
    assert res["evicted"] == [3]
    assert res["reasons"][3] == "heartbeat-timeout"
    assert c.alive_workers() == [0, 1, 2]


def test_straggler_eviction():
    clock = FakeClock()
    c = Coordinator(4, FTConfig(straggler_factor=3.0, straggler_patience=3),
                    clock=clock)
    for step in range(4):
        clock.t += 1.0
        for w in range(4):
            c.heartbeat(w, step, 10.0 if w == 2 else 1.0)
    res = c.sweep()
    assert res["evicted"] == [2]
    assert res["reasons"][2] == "straggler"


def test_min_workers_guard():
    clock = FakeClock()
    c = Coordinator(2, FTConfig(heartbeat_timeout=1.0, min_workers=2),
                    clock=clock)
    clock.t = 5.0
    with pytest.raises(RuntimeError):
        c.sweep()


def test_elastic_rejoin_bumps_generation():
    c = Coordinator(2)
    g0 = c.generation
    c.join(7)
    assert c.generation == g0 + 1
    assert 7 in c.alive_workers()


def test_recovery_resumes_from_checkpoint():
    """Crash at step 7 -> fleet drops worker, restores step-5 checkpoint,
    recomputes 5..10 with fewer data shards, ends at the same global state
    as the data-pipeline purity guarantees."""
    state = {"sum": 0.0, "ckpt": {}, "last_ckpt_step": 0}

    def train_one_step(step, workers):
        # each worker contributes a deterministic shard value: batch(step)
        # is pure, so shard union is identical regardless of worker count
        state["sum"] += sum(step * 1000 + i for i in range(8)) / 8

    def save_fn(step):
        state["ckpt"][step] = state["sum"]
        state["last_ckpt_step"] = step

    def restore_fn():
        step = state["last_ckpt_step"]
        state["sum"] = state["ckpt"].get(step, 0.0)
        return step

    log = run_with_recovery(train_one_step, num_workers=4, steps=10,
                            save_every=5, save_fn=save_fn,
                            restore_fn=restore_fn, fail_at={7: 2})
    events = [e[0] for e in log]
    assert "recover" in events
    rec = [e for e in log if e[0] == "recover"][0]
    assert rec[3] == 5          # restarted from checkpoint step 5
    assert rec[4] == 3          # fleet shrank to 3 data shards
    # final state equals a crash-free run
    expected = sum(s * 1000 + 3.5 for s in range(10))
    assert abs(state["sum"] - expected) < 1e-6


def test_data_pipeline_elastic_reshard():
    """Union of host shards is invariant to host count (what makes elastic
    rescale lossless)."""
    from repro.data.pipeline import PipelineConfig, TokenPipeline
    base = PipelineConfig(vocab_size=100, global_batch=8, seq_len=4,
                          num_hosts=1, host_id=0, seed=3)
    full = TokenPipeline(base).batch(5)["tokens"]
    parts = [TokenPipeline(base).reshard(4, h).batch(5)["tokens"]
             for h in range(4)]
    # every 4-host shard row appears in ... NOTE: resharding changes the
    # random stream per host; the invariant we guarantee is determinism
    # (same (hosts, host_id, step) -> same data) and shard disjointness.
    again = [TokenPipeline(base).reshard(4, h).batch(5)["tokens"]
             for h in range(4)]
    for a, b in zip(parts, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    flat = {tuple(r) for p in parts for r in np.asarray(p).tolist()}
    assert len(flat) == sum(p.shape[0] for p in parts)   # disjoint rows
