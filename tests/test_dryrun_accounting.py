"""Dry-run accounting: verified facts the roofline methodology rests on."""
import jax
import jax.numpy as jnp

from repro import compat


def test_cost_analysis_counts_scan_body_once():
    """XLA cost_analysis does NOT multiply loop bodies by trip count —
    the reason benchmarks/trip_expand.py exists."""
    def make(n):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        return f

    flops = []
    for n in (4, 8):
        comp = jax.jit(make(n)).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
        flops.append(compat.cost_analysis(comp).get("flops"))
    assert flops[0] == flops[1]


def test_collective_parser_expands_trip_counts():
    """Our HLO collective parser DOES multiply known_trip_count."""
    from repro.launch.dryrun import collective_bytes

    def f(x):
        def body(c, _):
            return c * 2.0, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    # single-device: no collectives, but the parser must still walk the
    # call graph without error and find nothing
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((16,), jnp.float32)).compile()
    cb = collective_bytes(comp.as_text())
    total = sum(v for k, v in cb.items() if k != "_counts")
    assert total == 0


def test_trip_expansion_factors_reasonable():
    """Expansion factor ~ #layers for single-scan-group archs."""
    import json
    from benchmarks.trip_expand import expand_record
    from repro.configs import ARCHS

    rec = {"status": "ok", "arch": "deepseek-67b", "shape": "train_4k",
           "flops": 1e12, "bytes_accessed": 1e12, "collective_bytes": {}}
    out = expand_record(dict(rec))
    # 95 scanned layers; logits outside is nonzero, so factor < 95
    assert 20 < out["trip_expansion_factor"] <= 95

    rec = {"status": "ok", "arch": "xlstm-125m", "shape": "train_4k",
           "flops": 1e12, "bytes_accessed": 1e12, "collective_bytes": {}}
    out = expand_record(dict(rec))
    assert out["trip_expansion_factor"] == 1.0   # fully unrolled layers


def test_perf_variants_c5_reseed_cell(monkeypatch, tmp_path, capsys):
    """The C5 cell must lower BOTH the reseed-on batched variant and its
    baseline (the old host-loop fallback path: fused + reseed), diff them,
    and print the reseed-on launch model — without ever touching the jnp
    records."""
    import json

    from repro.launch import kmeans_dryrun, perf_variants

    calls = []

    def fake_lower_all(multi_pod, backend="jnp", reseed_empty=False,
                       prune="none"):
        calls.append((backend, reseed_empty))
        suffix = perf_variants._kmeans_variant_suffix(backend, reseed_empty,
                                                      prune)
        rec = {"roofline": {"compute_s": 1.0, "memory_s": 2.0,
                            "collective_s": 3.0, "dominant": "collective_s"}}
        for stage in ("kmeans-pkmeans-iter", "kmeans-ipkmeans-s2s3"):
            (tmp_path / f"{stage}__16x16{suffix}.json").write_text(
                json.dumps(rec))

    monkeypatch.setattr(perf_variants, "OUT_DIR", tmp_path)
    monkeypatch.setattr(kmeans_dryrun, "lower_all", fake_lower_all)
    perf_variants.run_kmeans("C5")
    assert ("batched", True) in calls          # the variant
    assert ("fused", True) in calls            # the old-fallback baseline
    assert ("jnp", False) not in [c for c in calls]
    out = capsys.readouterr().out
    assert "reseed-on" in out and "per-stack launch model" in out
