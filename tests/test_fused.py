"""Fused single-pass Lloyd kernel: parity sweeps against the jnp oracle and
the two-kernel Pallas path, in interpret mode (the CI kernel gate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import KMeansParams, kmeans, kmeans_batched, lloyd_step
from repro.kernels import ops, ref

SHAPES = [
    (64, 2, 3),        # tiny, d < lane
    (300, 2, 5),       # the paper's own geometry
    (1000, 17, 7),     # odd everything (n, k, d all unpadded)
    (513, 64, 130),    # k crosses one block boundary
    (2048, 128, 256),  # aligned, multi-block in n and k
    (96, 160, 9),      # d > 128 (two lane groups)
]


def _data(n, d, k, dtype=jnp.float32, scale=3.0):
    kx, kc = jax.random.split(jax.random.key(n * d * k + 1))
    x = (jax.random.normal(kx, (n, d)) * scale).astype(dtype)
    c = (jax.random.normal(kc, (k, d)) * scale).astype(dtype)
    return x, c


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_ref(n, d, k, dtype):
    x, c = _data(n, d, k, dtype)
    s_f, cnt_f, sse_f = ops.lloyd_step_fused(x, c, interpret=True)
    s_r, cnt_r, sse_r = ref.lloyd_step_ref(x, c)
    # counts exact => labels agree point-for-point (random data, no ties)
    np.testing.assert_allclose(np.asarray(cnt_f), np.asarray(cnt_r),
                               rtol=1e-6)
    tol = 1e-3 if dtype == jnp.float32 else 0.2
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_r),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(float(sse_f), float(sse_r), rtol=tol)


@pytest.mark.parametrize("n,d,k", SHAPES[:4])
def test_fused_matches_two_kernel_path(n, d, k):
    """The fused sweep must reproduce assign_pallas + centroid_update_pallas
    exactly (same tile math, one pass instead of two)."""
    x, c = _data(n, d, k)
    w = jnp.ones((n,), jnp.float32)
    labels, mind = ops.assign(x, c, interpret=True)
    s2, cnt2 = ops.centroid_update(x, labels, w, k, interpret=True)
    sse2 = jnp.sum(mind)
    s_f, cnt_f, sse_f = ops.lloyd_step_fused(x, c, interpret=True)
    np.testing.assert_allclose(np.asarray(cnt_f), np.asarray(cnt2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(sse_f), float(sse2), rtol=1e-4)


@pytest.mark.parametrize("n,d,k", [(300, 5, 7), (513, 64, 130)])
def test_fused_masked_points(n, d, k):
    """Packed-subset semantics: weight-0 rows contribute nothing."""
    x, c = _data(n, d, k)
    w = (jax.random.uniform(jax.random.key(9), (n,)) > 0.3).astype(
        jnp.float32)
    s_f, cnt_f, sse_f = ops.lloyd_step_fused(x, c, w, interpret=True)
    s_r, cnt_r, sse_r = ref.lloyd_step_ref(x, c, w)
    np.testing.assert_allclose(np.asarray(cnt_f), np.asarray(cnt_r),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_r),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(sse_f), float(sse_r), rtol=1e-4)
    # sanity: masked total count is the number of surviving points
    assert float(cnt_f.sum()) == pytest.approx(float(w.sum()))


@pytest.mark.parametrize("n,d,k", SHAPES[:5])
def test_assign_only_bitwise_vs_full_sweep(n, d, k):
    """The assign-only fast path (``ops.lloyd_assign_fused``) elides the
    phase-2 accumulators but shares phase 1 verbatim: labels and distances
    must be bit-for-bit the full sweep's, and match the oracle."""
    x, c = _data(n, d, k)
    la, ma = ops.lloyd_assign_fused(x, c, interpret=True)
    from repro.kernels.fused import lloyd_step_fused
    _, _, _, lf, mf = lloyd_step_fused(x, c, interpret=True,
                                       return_labels=True)
    assert np.array_equal(np.asarray(la), np.asarray(lf))
    assert np.array_equal(np.asarray(ma), np.asarray(mf))
    lr, mr = ref.assign_ref(x, c)
    assert np.array_equal(np.asarray(la), np.asarray(lr))
    np.testing.assert_allclose(np.asarray(ma), np.asarray(mr),
                               rtol=1e-5, atol=1e-5)


def test_assign_only_rejects_weights():
    x, c = _data(64, 2, 3)
    from repro.kernels.fused import lloyd_step_fused
    with pytest.raises(ValueError, match="assign_only"):
        lloyd_step_fused(x, c, jnp.ones((64,)), interpret=True,
                         assign_only=True)


def test_fused_empty_clusters():
    """A centroid nothing maps to must come back with zero sum and count,
    and the solver step must then keep the old centroid."""
    x, c = _data(200, 4, 6)
    c = c.at[2].set(1e6)                       # unreachable centroid
    s_f, cnt_f, _ = ops.lloyd_step_fused(x, c, interpret=True)
    assert float(cnt_f[2]) == 0.0
    assert float(jnp.abs(s_f[2]).sum()) == 0.0
    new_c, _ = lloyd_step(x, c, backend="fused")
    np.testing.assert_allclose(np.asarray(new_c[2]), np.asarray(c[2]))


@pytest.mark.parametrize("block_n,block_k", [(128, 128), (256, 64), (64, 256)])
def test_fused_block_shape_invariance(block_n, block_k):
    from repro.kernels.specs import KernelSpec
    x, c = _data(700, 16, 200)
    s0, cnt0, sse0 = ref.lloyd_step_ref(x, c)
    s1, cnt1, sse1 = ops.lloyd_step_fused(
        x, c, spec=KernelSpec(block_n=block_n, block_k=block_k),
        interpret=True)
    np.testing.assert_allclose(np.asarray(cnt0), np.asarray(cnt1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(sse0), float(sse1), rtol=1e-4)


def test_lloyd_step_backend_parity():
    """One full solver step: fused backend == jnp backend (new centroids and
    shard SSE), with and without a mask."""
    x, c = _data(400, 6, 8)
    mask = jax.random.uniform(jax.random.key(3), (400,)) > 0.25
    for m in (None, mask):
        c_jnp, sse_jnp = lloyd_step(x, c, m, backend="jnp")
        c_fus, sse_fus = lloyd_step(x, c, m, backend="fused")
        np.testing.assert_allclose(np.asarray(c_jnp), np.asarray(c_fus),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(sse_jnp), float(sse_fus), rtol=1e-4)


def test_kmeans_solver_fused_backend():
    """Lloyd-to-convergence with backend='fused' tracks the jnp solver."""
    x, _ = _data(512, 6, 8)
    init = x[:8]
    r_jnp = kmeans(x, init, params=KMeansParams(max_iters=25))
    r_fus = kmeans(x, init, params=KMeansParams(max_iters=25,
                                                backend="fused"))
    assert int(r_jnp.iters) == int(r_fus.iters)
    np.testing.assert_allclose(np.asarray(r_jnp.centroids),
                               np.asarray(r_fus.centroids),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(r_jnp.sse), float(r_fus.sse), rtol=1e-4)


def test_ipkmeans_with_backend_parity():
    """The full three-stage pipeline is backend-invariant, switched via
    IPKMeansConfig.with_backend (the knob benchmarks and launchers use)."""
    from repro.core.ipkmeans import IPKMeansConfig, ipkmeans
    x, _ = _data(512, 6, 8)
    init = x[:8]
    cfg = IPKMeansConfig(num_clusters=8, num_subsets=4,
                         kmeans=KMeansParams(max_iters=15))
    base = ipkmeans(x, init, jax.random.key(0), cfg)
    for backend in ("pallas", "fused"):
        res = ipkmeans(x, init, jax.random.key(0), cfg.with_backend(backend))
        np.testing.assert_allclose(float(res.sse), float(base.sse),
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(res.centroids),
                                   np.asarray(base.centroids),
                                   rtol=1e-4, atol=1e-4)


def test_unknown_backend_raises():
    x, c = _data(64, 2, 3)
    with pytest.raises(ValueError, match="unknown backend"):
        lloyd_step(x, c, backend="fussed")


def test_kmeans_batched_fused_backend():
    """The fused kernel composes under vmap — the S2 per-device reducer
    stack runs it unchanged."""
    x, _ = _data(256, 4, 4)
    subsets = jnp.stack([x[:128], x[128:]])
    masks = jnp.ones((2, 128), bool).at[1, 100:].set(False)
    init = x[:4]
    p = KMeansParams(max_iters=10)
    r_jnp = kmeans_batched(subsets, masks, init, p)
    r_fus = kmeans_batched(subsets, masks, init,
                           p._replace(backend="fused"))
    np.testing.assert_allclose(np.asarray(r_jnp.centroids),
                               np.asarray(r_fus.centroids),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(r_jnp.asse),
                               np.asarray(r_fus.asse), rtol=1e-4)
