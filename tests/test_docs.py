"""The docs layer is executable and internally consistent: the README
quickstart runs as-is, and every intra-repo link/path the docs cite
exists.  This is CI's docs job (and part of tier-1)."""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
DOC_FILES = [REPO / "README.md",
             *sorted((REPO / "docs").glob("*.md"))]


def test_docs_exist():
    assert (REPO / "README.md").is_file()
    assert (REPO / "docs" / "kernels.md").is_file()
    assert (REPO / "docs" / "tuning.md").is_file()


def _python_blocks(md: str):
    return re.findall(r"```python\n(.*?)```", md, re.S)


def test_readme_quickstart_runs():
    """The quickstart is the first thing a user pastes — execute it
    verbatim (its own asserts are the correctness check)."""
    blocks = _python_blocks((REPO / "README.md").read_text())
    assert blocks, "README.md lost its ```python quickstart block"
    ns = {}
    exec(compile(blocks[0], "README.md:quickstart", "exec"), ns)
    assert "res" in ns, "quickstart no longer produces a result object"


def test_readme_names_tier1_command():
    md = (REPO / "README.md").read_text()
    assert "PYTHONPATH=src python -m pytest -x -q" in md


def test_readme_backend_table_covers_registry():
    """The backend-selection table must name every registered engine —
    a new engine without docs fails here, not in a user's terminal."""
    from repro.kernels import engine as engines
    from repro.kernels import tuning  # noqa: F401  (registers 'tuned')
    md = (REPO / "README.md").read_text()
    table = md[md.index("| backend"):]
    for name in engines.available():
        assert f"`{name}`" in table, f"engine {name!r} missing from README"


_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]*)(#[^)\s]*)?\)")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    h = heading.strip().lstrip("#").strip().lower()
    h = re.sub(r"[`*\"'()=.,/\\|]", "", h)
    return re.sub(r"\s+", "-", h.strip())


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_intra_repo_links_resolve(doc):
    """Every relative link target (file and #anchor) in the user-facing
    docs must exist; external URLs are out of scope."""
    md = doc.read_text()
    anchors_by_file = {}

    def anchors_of(path: Path):
        if path not in anchors_by_file:
            heads = re.findall(r"^#+ .+$", path.read_text(), re.M)
            anchors_by_file[path] = {_slug(h) for h in heads}
        return anchors_by_file[path]

    for m in _LINK.finditer(md):
        target, frag = m.group(1), m.group(2)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        dest = doc if not target else (doc.parent / target).resolve()
        assert dest.exists(), f"{doc.name}: broken link -> {target}"
        if frag and dest.suffix == ".md":
            assert frag[1:] in anchors_of(dest), \
                f"{doc.name}: dead anchor -> {target or doc.name}{frag}"


def test_docs_cite_real_code_paths():
    """Backtick-quoted repo paths in the docs must exist on disk — docs
    that name moved/renamed files rot silently otherwise.  experiments/
    is exempt: those are run artifacts, not source."""
    pat = re.compile(r"`((?:src|tests|benchmarks|docs)"
                     r"/[A-Za-z0-9_./-]+)`")
    for doc in DOC_FILES:
        for m in pat.finditer(doc.read_text()):
            p = REPO / m.group(1)
            assert p.exists(), f"{doc.name}: cites missing path {m.group(1)}"
