"""Bound-gated block skipping (prune="bounds"): the pruned solve must be
bit-for-bit identical to the exact solve — at the kernel, the oracle, and
the engine level — while actually skipping score passes late in converging
runs.  All in interpret mode (the CI kernel gate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import KMeansParams, kmeans, kmeans_batched
from repro.kernels import ops, ref, resident
from repro.kernels.resident import bound_block_rows, check_prune


def _np(a):
    """Bitwise-comparable numpy view (bf16 -> f32 is exact)."""
    a = jnp.asarray(a)
    if a.dtype == jnp.bfloat16:
        a = a.astype(jnp.float32)
    return np.asarray(a)


def _assert_bitwise(exact, pruned, msg=""):
    for i, (a, b) in enumerate(zip(exact, pruned)):
        np.testing.assert_array_equal(_np(a), _np(b),
                                      err_msg=f"{msg} output[{i}]")


def _data(n, d, k, dtype=jnp.float32, seed=1):
    kx, kc = jax.random.split(jax.random.key(n * d * k + seed))
    x = (3.0 * jax.random.normal(kx, (n, d))).astype(dtype)
    c = (3.0 * jax.random.normal(kc, (k, d))).astype(dtype)
    return x, c


def _clustered(n, d, k, noise=2.0, pert=6.0, seed=7):
    """Block-coherent clusters (rows grouped by true cluster) + a perturbed
    seed: converges over several iterations with wide per-block margins —
    the regime where the bound gate actually fires."""
    kc, kn, ki = jax.random.split(jax.random.key(seed), 3)
    centers = 8.0 * jax.random.normal(kc, (k, d), jnp.float32)
    ids = jnp.sort(jnp.arange(n) % k)
    x = centers[ids] + noise * jax.random.normal(kn, (n, d), jnp.float32)
    init = centers + pert * jax.random.normal(ki, (k, d), jnp.float32)
    return x, init


# ----------------------------------------------------------- validation ----

def test_check_prune_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown prune mode"):
        check_prune("nope")
    check_prune("none")
    check_prune("bounds")


@pytest.mark.parametrize("backend", ["jnp", "fused", "resident", "batched"])
def test_engines_reject_unknown_prune(backend):
    x, c = _data(64, 3, 4)
    with pytest.raises(ValueError, match="unknown prune mode"):
        kmeans(x, c, params=KMeansParams(max_iters=2, backend=backend,
                                         prune="hamerly"))


def test_bound_block_rows_divides_exactly():
    # exact division keeps the pruned padded row count == the exact path's
    for n_pad in (8, 64, 96, 256, 328, 2048):
        bb = bound_block_rows(n_pad)
        assert bb % 8 == 0 and n_pad % bb == 0
    assert bound_block_rows(96, 64) == 48
    assert bound_block_rows(2048, 256) == 256


# -------------------------------------------------------------- oracle -----

@pytest.mark.parametrize("n,d,k", [(300, 2, 5), (257, 17, 7)])
def test_bounds_oracle_matches_exact_oracle(n, d, k):
    """lloyd_solve_bounds_ref must reproduce lloyd_solve_ref bitwise: the
    skipped blocks reuse cached labels in the SAME segment-sum contraction,
    so an unsound bound shows up as a divergence here."""
    x, _ = _data(n, d, k)
    init = x[:k]
    exact = ref.lloyd_solve_ref(x, init, max_iters=40, tol=1e-6)
    pruned = ref.lloyd_solve_bounds_ref(x, init, max_iters=40, tol=1e-6,
                                        block_rows=64)
    _assert_bitwise(exact, pruned[:4], "bounds oracle")


def test_bounds_oracle_skips_on_converging_workload():
    x, init = _clustered(512, 4, 8)
    out = ref.lloyd_solve_bounds_ref(x, init, max_iters=24, tol=0.0,
                                     block_rows=64)
    skips = np.asarray(out[4])[:int(out[2])]
    assert skips[0, 0] == 0                     # no bounds yet at iter 0
    assert skips[:, 0].sum() > 0                # ...but they fire later


# ----------------------------------------------------- resident kernel -----

@pytest.mark.parametrize("n,d,k", [(300, 2, 5), (512, 6, 8), (257, 17, 7)])
@pytest.mark.parametrize("masked", [False, True])
def test_resident_pruned_bitwise_parity(n, d, k, masked):
    x, _ = _data(n, d, k)
    init = x[:k]
    w = None
    if masked:
        w = (jax.random.uniform(jax.random.key(9), (n,)) > 0.2).astype(
            jnp.float32)
    exact = ops.lloyd_solve_resident(x, init, w, max_iters=30, tol=1e-6,
                                     interpret=True)
    pruned = ops.lloyd_solve_resident(x, init, w, max_iters=30, tol=1e-6,
                                      interpret=True, prune="bounds",
                                      bound_block=64)
    _assert_bitwise(exact, pruned, f"resident n={n} masked={masked}")


def test_resident_pruned_skip_counters_rise_late():
    """Directed: on a block-coherent converging workload the per-iteration
    skip fraction must start at zero and be NONZERO in the late iterations
    (the whole point of carrying the bounds)."""
    x, init = _clustered(2048, 8, 8)
    out = ops.lloyd_solve_resident(x, init, max_iters=24, tol=0.0,
                                   interpret=True, prune="bounds",
                                   bound_block=256, return_skips=True)
    iters = int(out[2])
    skips = np.asarray(out[4])
    assert skips.shape == (24, 2)
    trace = skips[:iters]
    assert iters >= 3
    assert trace[0, 0] == 0                     # margins start at -inf
    assert (trace[:, 0] <= trace[:, 1]).all()
    late = trace[iters // 2:]
    assert late[:, 0].sum() > 0, trace.tolist()
    # fraction rises: the last iteration skips at least as much as the first
    assert trace[-1, 0] >= trace[0, 0]
    # rows past convergence stay zeroed
    assert (skips[iters:] == 0).all()


def test_resident_exact_skip_counters_are_zero():
    x, _ = _data(256, 4, 4)
    out = ops.lloyd_solve_resident(x, x[:4], max_iters=10, tol=1e-6,
                                   interpret=True, return_skips=True)
    assert np.asarray(out[4]).shape == (10, 2)
    assert (np.asarray(out[4]) == 0).all()


def test_resident_pruned_with_reseed_bitwise():
    """Pruning composes with the in-kernel empty-cluster reseed: a
    far-planted centroid forces reseeds to fire, and the pruned solve must
    still match the exact reseeding solve bitwise."""
    x, _ = _data(256, 2, 3)
    init = jnp.array([[0.0, 0.0], [0.5, 0.5], [500.0, 500.0]], x.dtype)
    exact = ops.lloyd_solve_resident(x, init, max_iters=20, tol=1e-6,
                                     interpret=True, reseed_empty=True)
    pruned = ops.lloyd_solve_resident(x, init, max_iters=20, tol=1e-6,
                                      interpret=True, reseed_empty=True,
                                      prune="bounds", bound_block=64)
    _assert_bitwise(exact, pruned, "resident reseed-on")


def test_resident_pruned_parity_property():
    """hypothesis sweep: shapes x dtypes x masks x reseed, pruned vs exact
    bitwise.  Shapes come from a small menu so the jit cache is shared."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the 'dev' extra (pip install -e '.[dev]')")
    from hypothesis import given, settings, strategies as st

    @given(st.sampled_from([(64, 4, 4), (96, 3, 8), (128, 5, 4), (61, 2, 3)]),
           st.sampled_from([jnp.float32, jnp.bfloat16]),
           st.booleans(), st.booleans(), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def prop(shape, dtype, masked, reseed, seed):
        n, d, k = shape
        x, _ = _data(n, d, k, dtype, seed=seed % 1000)
        init = x[:k]
        w = None
        if masked:
            w = (jax.random.uniform(jax.random.key(seed % 997), (n,))
                 > 0.3).astype(jnp.float32)
        exact = ops.lloyd_solve_resident(
            x, init, w, max_iters=15, tol=1e-6, interpret=True,
            reseed_empty=reseed)
        pruned = ops.lloyd_solve_resident(
            x, init, w, max_iters=15, tol=1e-6, interpret=True,
            reseed_empty=reseed, prune="bounds", bound_block=64)
        _assert_bitwise(exact, pruned, f"{shape} {dtype} m={masked}")

    prop()


# ------------------------------------------------------ batched kernel -----

def _stack(m, s, d, dtype=jnp.float32, seed=0):
    kx, kw = jax.random.split(jax.random.key(seed))
    x = (3.0 * jax.random.normal(kx, (m, s, d))).astype(dtype)
    w = (jax.random.uniform(kw, (m, s)) > 0.2).astype(jnp.float32)
    return x, w


@pytest.mark.parametrize("m,s,d,k", [(4, 64, 4, 4), (6, 96, 3, 8)])
def test_batched_pruned_bitwise_parity(m, s, d, k):
    x, w = _stack(m, s, d)
    init = x[0, :k]
    exact = ops.lloyd_solve_batched(x, init, w, group_t=2, max_iters=20,
                                    tol=1e-6, interpret=True)
    pruned = ops.lloyd_solve_batched(x, init, w, group_t=2, max_iters=20,
                                     tol=1e-6, interpret=True,
                                     prune="bounds", bound_block=64)
    _assert_bitwise(exact, pruned, f"batched m={m}")


def test_batched_pruned_reseed_bitwise_and_counters():
    x, w = _stack(4, 64, 2, seed=3)
    init = jnp.array([[0.0, 0.0], [0.5, 0.5], [500.0, 500.0]], x.dtype)
    exact = ops.lloyd_solve_batched(x, init, w, group_t=2, max_iters=20,
                                    tol=1e-6, interpret=True,
                                    reseed_empty=True)
    pruned = ops.lloyd_solve_batched(x, init, w, group_t=2, max_iters=20,
                                     tol=1e-6, interpret=True,
                                     reseed_empty=True, prune="bounds",
                                     bound_block=64, return_skips=True)
    _assert_bitwise(exact, pruned[:4], "batched reseed-on")
    skips = np.asarray(pruned[4])
    assert skips.shape == (20, 2)
    assert (skips >= 0).all() and (skips[:, 0] <= skips[:, 1]).all()


# ------------------------------------------------------------ engines ------

@pytest.mark.parametrize("backend", ["jnp", "fused", "resident", "batched",
                                     "tuned"])
def test_kmeans_prune_is_identity_on_every_engine(backend):
    """KMeansParams.prune='bounds' must be result-invisible on EVERY
    engine: kernel engines prune for real (bitwise contract), host-loop
    engines validate-and-ignore (their exact loop IS the pruned result)."""
    x, _ = _data(400, 3, 4)
    init = x[:4]
    base = kmeans(x, init, params=KMeansParams(max_iters=25, backend=backend))
    pruned = kmeans(x, init, params=KMeansParams(max_iters=25,
                                                 backend=backend,
                                                 prune="bounds"))
    _assert_bitwise(base, pruned, backend)


def test_kmeans_batched_prune_is_identity():
    x, w = _stack(4, 64, 4, seed=5)
    init = x[0, :4]
    base = kmeans_batched(x, w, init, params=KMeansParams(
        max_iters=20, backend="batched"))
    pruned = kmeans_batched(x, w, init, params=KMeansParams(
        max_iters=20, backend="batched", prune="bounds"))
    _assert_bitwise(base, pruned, "kmeans_batched")


def test_ipkmeans_with_prune_threads_through():
    from repro.core import IPKMeansConfig, ipkmeans
    x, _ = _data(256, 3, 4)
    key = jax.random.key(0)
    cfg = IPKMeansConfig(num_clusters=4, num_subsets=4,
                         kmeans=KMeansParams(max_iters=15))
    base = ipkmeans(x, x[:4], key, cfg)
    pruned = ipkmeans(x, x[:4], key, cfg.with_prune("bounds"))
    assert cfg.with_prune("bounds").kmeans.prune == "bounds"
    _assert_bitwise(
        (base.centroids, base.sse, base.intermediate, base.asses),
        (pruned.centroids, pruned.sse, pruned.intermediate, pruned.asses),
        "ipkmeans")


# -------------------------------------------------------- vmem model -------

def test_prune_vmem_model_is_monotone():
    for n, d, k in [(256, 4, 4), (2048, 8, 8), (4096, 64, 256)]:
        exact = resident.resident_vmem_bytes(n, d, k)
        pruned = resident.resident_vmem_bytes(n, d, k, prune="bounds")
        assert pruned > exact
    # the prune-aware inversion stays exact: the max feasible n still fits,
    # the next 8-row granule does not
    for d, k in [(2, 5), (16, 64)]:
        n_max = resident.max_resident_points(d, k, prune="bounds")
        assert resident.resident_feasible(n_max, d, k, prune="bounds")
        assert not resident.resident_feasible(n_max + 8, d, k,
                                              prune="bounds")
        assert n_max <= resident.max_resident_points(d, k)
