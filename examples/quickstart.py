"""Quickstart: cluster a Gaussian dataset with IPKMeans vs PKMeans.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's headline in 30 seconds: same initial centroids, one
single parallel program for IPKMeans vs an iteration-synchronous PKMeans,
near-identical SSE, and the job/I-O arithmetic that favours IPKMeans.
"""
import time

import jax

from repro.core import IPKMeansConfig, io_model, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_3000


def main():
    points, _ = paper_dataset_3000(seed=0)
    init = initial_centroid_groups(points, k=5, groups=1)[0]

    t0 = time.time()
    ref = pkmeans(points, init)
    t_pk = time.time() - t0
    print(f"PKMeans : SSE={float(ref.sse):10.2f}  "
          f"Lloyd iters={int(ref.iters)}  ({t_pk:.2f}s)")

    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)   # 6 'reducers'
    t0 = time.time()
    res = ipkmeans(points, init, jax.random.key(0), cfg)
    t_ipk = time.time() - t0
    print(f"IPKMeans: SSE={float(res.sse):10.2f}  "
          f"kd-tree depth={res.kd_depth}  ({t_ipk:.2f}s)")
    print(f"SSE gap: {100 * (float(res.sse) / float(ref.sse) - 1):.3f}%")

    model = io_model.HadoopCostModel()
    pk = model.pkmeans_bytes(3000, 2, 5, int(ref.iters))
    ipk = model.ipkmeans_bytes(3000, 2, 5, 6, res.kd_depth)
    print(f"MapReduce jobs : PKMeans={pk['jobs']}  IPKMeans={ipk['jobs']}")
    tot_pk = pk["read"] + pk["write"]
    tot_ipk = ipk["read"] + ipk["write"]
    print(f"modeled I/O    : PKMeans={tot_pk/1e6:.1f}MB  "
          f"IPKMeans={tot_ipk/1e6:.1f}MB  "
          f"({100 * (1 - tot_ipk / tot_pk):.0f}% lower)")


if __name__ == "__main__":
    main()
