"""End-to-end driver: train a ~110M-parameter LM for a few hundred steps
with checkpoints, WSD schedule, and resumable data.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Uses a 110M xLSTM-family config (the assigned xlstm-125m scaled to CPU-
trainable sequence length).  Loss should fall from ~ln(vocab)≈9.2 toward
~5-6 within a few hundred steps on the synthetic stream.
"""
import argparse
import dataclasses
import functools

from repro import optim
from repro.configs import ARCHS
from repro.launch.train import train_loop
from repro.optim import schedules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # the real xlstm-125m config, CPU-adapted: f32, no remat, short chunks
    cfg = dataclasses.replace(ARCHS["xlstm-125m"], dtype="float32",
                              remat="none")
    print(f"model: {cfg.name}  params≈"
          f"{cfg.param_count()/1e6:.0f}M  steps={args.steps}")
    schedule = functools.partial(schedules.wsd, peak_lr=3e-4, warmup=20,
                                 stable=int(args.steps * 0.7),
                                 decay=int(args.steps * 0.2))
    _, _, history = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10,
        adamw_cfg=optim.AdamWConfig(weight_decay=0.01),
        schedule=schedule)
    first, last = history[0][1], history[-1][1]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
