"""Train a VQ codebook over patch embeddings with IPKMeans (the chameleon
touchpoint: VQ image tokens ARE k-means codes).

    PYTHONPATH=src python examples/cluster_embeddings.py [--codebook 64]

Synthesizes patch embeddings from a mixture (standing in for a VQ-VAE
encoder's outputs), learns a codebook with distributed IPKMeans, and reports
quantization error + codebook utilization vs a PKMeans-trained codebook.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import IPKMeansConfig, KMeansParams, ipkmeans, pkmeans
from repro.data import gaussian_mixture, initial_centroid_groups
from repro.kernels import engine as engines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--patches", type=int, default=16384)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--codebook", type=int, default=64)
    ap.add_argument("--reducers", type=int, default=16)
    ap.add_argument("--backend", default="jnp",
                    choices=list(engines.available()),
                    help="Lloyd engine for the solves AND the final "
                         "patch->code assignment (on TPU, 'fused' gets the "
                         "codes from the kernel's labels output instead of "
                         "materializing the (n, k) distance matrix)")
    args = ap.parse_args()

    embeds, _, _ = gaussian_mixture(jax.random.key(0), args.patches,
                                    args.codebook, d=args.dim)
    init = initial_centroid_groups(embeds, args.codebook, groups=1)[0]
    eng = engines.get_engine(args.backend)

    t0 = time.time()
    ref = pkmeans(embeds, init,
                  params=KMeansParams(backend=args.backend))
    t_pk = time.time() - t0

    cfg = IPKMeansConfig(num_clusters=args.codebook,
                         num_subsets=args.reducers).with_backend(args.backend)
    t0 = time.time()
    res = ipkmeans(embeds, init, jax.random.key(1), cfg)
    t_ipk = time.time() - t0

    for name, codebook, t in (("PKMeans ", ref.centroids, t_pk),
                              ("IPKMeans", res.centroids, t_ipk)):
        codes, mind = eng.assign(embeds, codebook)
        used = len(jnp.unique(codes))
        mse = float(jnp.mean(mind))
        print(f"{name}: quantization MSE={mse:.4f}  "
              f"codebook use={used}/{args.codebook}  ({t:.2f}s)")


if __name__ == "__main__":
    main()
