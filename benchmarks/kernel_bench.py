"""Kernel micro-bench: Pallas assignment / update vs jnp reference.

On this CPU container the Pallas kernels execute under interpret=True (a
Python interpreter — not meaningful for wall-clock), so the timed comparison
is jnp-reference vs jnp-reference-at-scale; the Pallas numbers reported are
correctness-path timings only.  The real target is the TPU lowering, whose
tiling is validated structurally (block shapes, VMEM footprint) here."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import record, timeit
from repro.kernels import ops, ref

SIZES = [(10_000, 2, 5), (100_000, 16, 64), (500_000, 64, 256)]


def vmem_footprint(bn, bk, d_pad, dtype_bytes=4):
    """Bytes of VMEM the assign kernel's working set claims per grid step."""
    return (bn * d_pad + bk * d_pad + bk + 2 * bn) * dtype_bytes


def run():
    rows = []
    for n, d, k in SIZES:
        kx, kc = jax.random.split(jax.random.key(n))
        x = jax.random.normal(kx, (n, d), jnp.float32)
        c = jax.random.normal(kc, (k, d), jnp.float32)
        fn = jax.jit(lambda x, c: ref.assign_ref(x, c))
        t = timeit(fn, x, c)
        bn, bk = 256, 128
        d_pad = max(-(-d // 128) * 128, 128)
        rows.append({
            "n": n, "d": d, "k": k,
            "jnp_ref_us": t * 1e6,
            "flops": 2.0 * n * k * d,
            "gflops_per_s": 2.0 * n * k * d / t / 1e9,
            "pallas_block": [bn, bk, d_pad],
            "pallas_vmem_bytes": vmem_footprint(bn, bk, d_pad),
            "vmem_ok": vmem_footprint(bn, bk, d_pad) < 16 * 2 ** 20,
        })
    record("kernel_bench", rows,
           ("kernel_assign", f"{rows[-1]['jnp_ref_us']:.0f}",
            f"gflops={rows[-1]['gflops_per_s']:.1f}"))
    return rows


if __name__ == "__main__":
    run()
