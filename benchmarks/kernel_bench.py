"""Kernel micro-bench: Pallas assignment / update / fused / resident Lloyd
engines vs jnp ref.

On this CPU container the Pallas kernels execute under interpret=True (a
Python interpreter — not meaningful for wall-clock), so the timed comparison
is jnp-reference vs jnp-reference-at-scale; the Pallas numbers reported are
correctness-path timings only.  The real target is the TPU lowering, whose
tiling is validated structurally here: block shapes, VMEM footprints, and the
HBM-traffic models that quantify the wins — per *iteration*, the fused
single-pass kernel reads the points once instead of twice with no ``(n,)``
label/distance round-trip; per *solve*, the VMEM-resident engine reads the
points ONCE TOTAL, so its projected per-solve traffic is ~1/iters of the
fused engine's (which pays one sweep every iteration); per *stack*, the
batched megakernel turns a device's M reducers into ceil(M/T) pipelined
grid steps (vs M serialized single-block steps under vmap) with the whole
stack's points still read once per solve — including with
``reseed_empty=True``, where the in-kernel farthest-point reseed keeps the
launch count at ceil(M/T) instead of the vmap-of-host-solve fallback the
flag used to force (the reseed-on row times both paths head-to-head).  The
pruned row runs the same resident solve with ``prune="bounds"`` and reports
the per-iteration fraction of point blocks whose score matmul the bound
gate skipped — rising toward convergence on a clustering workload — along
with the bitwise-equality check the pruning contract requires.  The init
row solves a clustered workload end to end (seeding + Lloyd, same data and
key) under k-means|| vs the sample baseline and snapshots final SSE,
per-seed and median iterations-to-converge, and the e2e solve time — the
deltas the fused init sweeps are accountable for.

``benchmarks.run --smoke`` snapshots this module's rows to
``BENCH_kernel.json`` at the repo root, so the perf trajectory accumulates
across commits."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import record, timeit
from repro.kernels import ops, ref, specs, tuning
from repro.kernels.batch_resident import (batched_group_size,
                                          batched_group_vmem_bytes)
from repro.kernels.resident import resident_feasible, resident_vmem_bytes
from repro.kernels.specs import F32

SIZES = [(10_000, 2, 5), (100_000, 16, 64), (500_000, 64, 256)]
NOMINAL_ITERS = 20  # typical Lloyd iterations-to-convergence for the models

# working-set pricing lives on KernelSpec (specs.py) — the same byte models
# the tuner prunes candidates with, so the report can't drift from the guard


def lloyd_hbm_bytes(n, d, k, fused: bool):
    """Analytic HBM traffic of ONE Lloyd iteration (f32).

    two-kernel: assign reads the points and writes (labels, mind); the
    update kernel re-reads the points plus (labels, weights) — the n*d
    stream happens twice and 4 (n,) vectors round-trip in between.
    fused: the points stream once, weights ride along, and only the
    (k,d)+(k,)+() accumulators come back.
    """
    small = k * d * F32 * 2 + k * F32          # centroids in, sums/counts out
    if fused:
        return n * d * F32 + n * F32 + small
    return (2 * n * d * F32                    # points read twice
            + 4 * n * F32                      # labels+mind out, labels+w in
            + small)


def lloyd_solve_hbm_bytes(n, d, k, iters, engine: str):
    """Analytic HBM traffic of a WHOLE Lloyd solve (f32) for an engine.

    Per-step engines ('pallas', 'fused') re-stream the points every
    iteration, so per-solve cost is ``iters x`` the per-iteration model.
    The 'resident' engine streams the points (and weights) across the HBM
    boundary once per solve — init centroids in, converged centroids and
    the (sse, iters, converged) scalars out — so its per-solve bytes sit at
    ~1/iters of the fused engine's for VMEM-feasible shapes.
    """
    if engine == "resident":
        return (n * d * F32 + n * F32          # points + weights, ONCE
                + 2 * k * d * F32 + 3 * F32)   # init in, final out, scalars
    return iters * lloyd_hbm_bytes(n, d, k, fused=(engine == "fused"))


def lloyd_stack_hbm_bytes(m, s, d, k, iters, engine: str, group_t: int = 1):
    """Analytic HBM traffic of a STACK of M solves (f32) for an engine.

    'batched' reads the whole stack's points+weights ONCE per stack solve
    and the shared init centroids once per grid step (ceil(M/T) groups),
    writing M converged centroid sets + per-subset scalars back.  The vmap
    of 'resident' moves the same points once per subset grid step — equal
    point bytes, M init-centroid reads instead of M/T — so the byte model
    alone is near-parity: the batched win is structural (launch count M ->
    ceil(M/T), input pipelining overlapping the next group's HBM stream
    with the current group's iterations, and group-batched MXU shapes),
    which the launch-count column quantifies.
    """
    launches = -(-m // group_t) if engine == "batched" else m
    if engine in ("batched", "resident"):
        return (m * s * d * F32 + m * s * F32  # the whole stack, ONCE
                + launches * k * d * F32       # shared init, per launch
                + m * (k * d + 3) * F32)       # finals + scalars out
    return m * lloyd_solve_hbm_bytes(s, d, k, iters, engine)


def run():
    rows = []
    for n, d, k in SIZES:
        kx, kc = jax.random.split(jax.random.key(n))
        x = jax.random.normal(kx, (n, d), jnp.float32)
        c = jax.random.normal(kc, (k, d), jnp.float32)
        fn = jax.jit(lambda x, c: ref.assign_ref(x, c))
        t = timeit(fn, x, c)
        # the kernels' actual tiling (block sizes clamp on small shapes)
        spec = specs.DEFAULT_SPEC
        bn, bk, _, k_pad, d_pad = spec.tile_shapes(n, d, k)
        budget = specs.get_profile().budget_bytes
        # fused vs two-kernel: one HBM sweep per iteration instead of two
        two_pass = lloyd_hbm_bytes(n, d, k, fused=False)
        fused = lloyd_hbm_bytes(n, d, k, fused=True)
        t_lloyd = timeit(jax.jit(lambda x, c: ref.lloyd_step_ref(x, c)), x, c)
        rows.append({
            "n": n, "d": d, "k": k,
            "jnp_ref_us": t * 1e6,
            "jnp_lloyd_step_us": t_lloyd * 1e6,
            "flops": 2.0 * n * k * d,
            "gflops_per_s": 2.0 * n * k * d / t / 1e9,
            "pallas_block": [bn, bk, d_pad],
            "pallas_vmem_bytes": spec.assign_vmem_bytes(n, d, k),
            "vmem_ok": spec.assign_vmem_bytes(n, d, k) <= budget,
            "fused_vmem_bytes": spec.fused_vmem_bytes(n, d, k),
            "fused_vmem_ok": spec.fused_vmem_bytes(n, d, k) <= budget,
            "hbm_bytes_two_pass": two_pass,
            "hbm_bytes_fused": fused,
            "fused_hbm_ratio": two_pass / fused,
            # per-SOLVE: resident streams points once, fused once per iter
            "resident_vmem_bytes": resident_vmem_bytes(n, d, k),
            "resident_vmem_ok": resident_feasible(n, d, k),
            "hbm_bytes_solve_fused":
                lloyd_solve_hbm_bytes(n, d, k, NOMINAL_ITERS, "fused"),
            "hbm_bytes_solve_resident":
                lloyd_solve_hbm_bytes(n, d, k, NOMINAL_ITERS, "resident"),
            "resident_solve_hbm_ratio":
                lloyd_solve_hbm_bytes(n, d, k, NOMINAL_ITERS, "fused")
                / lloyd_solve_hbm_bytes(n, d, k, NOMINAL_ITERS, "resident"),
        })

    # correctness-path comparison row (interpret mode, smallest size only —
    # wall-clock of the Python interpreter is NOT the TPU story, the row
    # exists so CI exercises the fused path end-to-end inside the harness)
    n, d, k = SIZES[0]
    kx, kc = jax.random.split(jax.random.key(n))
    x = jax.random.normal(kx, (n, d), jnp.float32)
    c = jax.random.normal(kc, (k, d), jnp.float32)
    w = jnp.ones((n,), jnp.float32)

    def two_kernel(x, c):
        labels, mind = ops.assign(x, c, interpret=True)
        sums, counts = ops.centroid_update(x, labels, w, k, interpret=True)
        return sums, counts, jnp.sum(mind)

    assign_row = rows[-1]                      # largest size's assign timing
    t_two = timeit(jax.jit(two_kernel), x, c)
    t_fus = timeit(jax.jit(
        lambda x, c: ops.lloyd_step_fused(x, c, interpret=True)), x, c)
    fused_row = {
        "n": n, "d": d, "k": k, "mode": "interpret-correctness-path",
        "pallas_two_kernel_us": t_two * 1e6,
        "pallas_fused_us": t_fus * 1e6,
        "hbm_bytes_two_pass": lloyd_hbm_bytes(n, d, k, fused=False),
        "hbm_bytes_fused": lloyd_hbm_bytes(n, d, k, fused=True),
        "fused_hbm_ratio": (lloyd_hbm_bytes(n, d, k, fused=False)
                            / lloyd_hbm_bytes(n, d, k, fused=True)),
    }
    rows.append(fused_row)

    # resident vs fused: a whole 8-iteration solve, one kernel launch vs a
    # host loop of per-step launches.  Both sides use ops' default interpret
    # policy (interpreted on CPU, compiled on TPU) so the comparison is
    # always mode-matched; the row exists so CI exercises engine.solve
    # through the real kernel, and to report the per-solve HBM model
    # head-to-head.
    n, d, k = SIZES[0]
    solve_iters = 8
    init_c = x[:k]
    t_res = timeit(jax.jit(lambda x, c: ops.lloyd_solve_resident(
        x, c, max_iters=solve_iters, tol=0.0)[0]), x, init_c)
    from repro.kernels.engine import get_engine
    t_fus_solve = timeit(jax.jit(lambda x, c: get_engine("fused").solve(
        x, c, max_iters=solve_iters, tol=0.0)[0]), x, init_c)
    resident_row = {
        "n": n, "d": d, "k": k, "mode": "interpret-resident-vs-fused-solve",
        "solve_iters": solve_iters,
        "resident_solve_us": t_res * 1e6,
        "fused_stepwise_solve_us": t_fus_solve * 1e6,
        "resident_vmem_ok": resident_feasible(n, d, k),
        "hbm_bytes_solve_fused":
            lloyd_solve_hbm_bytes(n, d, k, solve_iters, "fused"),
        "hbm_bytes_solve_resident":
            lloyd_solve_hbm_bytes(n, d, k, solve_iters, "resident"),
        "resident_solve_hbm_ratio":
            lloyd_solve_hbm_bytes(n, d, k, solve_iters, "fused")
            / lloyd_solve_hbm_bytes(n, d, k, solve_iters, "resident"),
    }
    rows.append(resident_row)

    # batched vs vmap(resident): a whole S2 reducer STACK (M subsets), one
    # pipelined multi-group launch vs the serialized grid of single-block
    # kernels vmap produces.  Both stream the stack's points once per solve;
    # the structural win is the launch count (M -> ceil(M/T)) and the
    # input-pipelining overlap, which interpret-mode wall-clock cannot show —
    # the row exists so CI exercises solve_batched end to end and reports
    # the launch/byte models head-to-head.
    m_stack, s_sub, d_b, k_b = 8, 64, 4, 4
    solve_iters = 8
    kx, kc = jax.random.split(jax.random.key(m_stack * s_sub))
    stack = jax.random.normal(kx, (m_stack, s_sub, d_b), jnp.float32)
    init_b = jax.random.normal(kc, (k_b, d_b), jnp.float32)
    # explicit group_t: T=1 keeps this interpret-mode row alive even on a
    # host whose budget would refuse the auto-derivation
    group_t = max(1, batched_group_size(m_stack, s_sub, d_b, k_b))
    t_bat = timeit(jax.jit(lambda x, c: ops.lloyd_solve_batched(
        x, c, group_t=group_t, max_iters=solve_iters, tol=0.0)[0]),
        stack, init_b)
    t_vmap = timeit(jax.jit(jax.vmap(
        lambda x, c: ops.lloyd_solve_resident(
            x, c, max_iters=solve_iters, tol=0.0)[0],
        in_axes=(0, None))), stack, init_b)
    batched_row = {
        "m": m_stack, "s": s_sub, "d": d_b, "k": k_b,
        "mode": "interpret-batched-vs-vmap-resident-stack",
        "solve_iters": solve_iters, "group_t": group_t,
        "launches_batched": -(-m_stack // group_t),
        "launches_vmap_resident": m_stack,
        "batched_stack_us": t_bat * 1e6,
        "vmap_resident_stack_us": t_vmap * 1e6,
        "group_vmem_bytes": batched_group_vmem_bytes(group_t, s_sub,
                                                     d_b, k_b),
        "group_vmem_share": (batched_group_vmem_bytes(group_t, s_sub,
                                                      d_b, k_b)
                             / specs.get_profile().budget_bytes),
        "subset_vmem_share": (resident_vmem_bytes(s_sub, d_b, k_b)
                              / specs.get_profile().budget_bytes),
        "hbm_bytes_stack_batched":
            lloyd_stack_hbm_bytes(m_stack, s_sub, d_b, k_b, solve_iters,
                                  "batched", group_t),
        "hbm_bytes_stack_vmap_resident":
            lloyd_stack_hbm_bytes(m_stack, s_sub, d_b, k_b, solve_iters,
                                  "resident"),
        "hbm_bytes_stack_fused":
            lloyd_stack_hbm_bytes(m_stack, s_sub, d_b, k_b, solve_iters,
                                  "fused"),
    }
    rows.append(batched_row)

    # reseed-on stack: the paper-pipeline quality configuration
    # (reseed_empty=True) used to force the stack OFF the megakernel onto
    # the vmap-of-host-solve fallback (M per-subset host loops, one fused
    # kernel launch per iteration each); the in-kernel farthest-point
    # reseed keeps it at ceil(M/T) pipelined launches.  Head-to-head:
    # megakernel with in-kernel reseed vs the old fallback path, same
    # empties-producing stack (far-planted init guarantees reseeds fire).
    from repro.kernels.engine import LloydEngine, get_engine
    far_init = init_b + 100.0
    t_bat_rs = timeit(jax.jit(lambda x, c: ops.lloyd_solve_batched(
        x, c, group_t=group_t, max_iters=solve_iters, tol=0.0,
        reseed_empty=True)[0]), stack, far_init)
    fused_eng = get_engine("fused")
    t_old_fallback = timeit(jax.jit(lambda x, c: LloydEngine.solve_batched(
        fused_eng, x, c, max_iters=solve_iters, tol=0.0,
        reseed_empty=True)[0]), stack, far_init)
    reseed_row = {
        "m": m_stack, "s": s_sub, "d": d_b, "k": k_b,
        "mode": "interpret-reseed-batched-vs-old-vmap-fallback",
        "solve_iters": solve_iters, "group_t": group_t,
        "reseed_empty": True,
        "launches_batched_reseed": -(-m_stack // group_t),
        "launches_old_fallback": m_stack,          # per ITERATION, host loop
        "batched_reseed_stack_us": t_bat_rs * 1e6,
        "old_vmap_fallback_stack_us": t_old_fallback * 1e6,
        "hbm_bytes_stack_batched":
            lloyd_stack_hbm_bytes(m_stack, s_sub, d_b, k_b, solve_iters,
                                  "batched", group_t),
        "hbm_bytes_stack_fused_fallback":
            lloyd_stack_hbm_bytes(m_stack, s_sub, d_b, k_b, solve_iters,
                                  "fused"),
    }
    rows.append(reseed_row)

    # bound-pruned vs exact resident solve: identical solve, except
    # prune="bounds" carries per-block margins + accumulated centroid drift
    # through the on-chip loop and skips a block's score matmul whenever the
    # triangle-inequality bound proves no assignment in it can change.
    # The workload is built to show the knob's regime: rows grouped by true
    # cluster (so point blocks are spatially coherent and carry wide
    # margins) with a perturbed-centers seed that takes several iterations
    # to settle — the skip fraction RISES toward convergence, exactly the
    # late-iteration behaviour the bound gate monetizes.  The contract is
    # bitwise equality with the exact path, asserted here on every output
    # field.
    import numpy as np
    n_p, d_p, k_p = 2048, 8, 8
    prune_iters = 24
    bound_block = 256
    kc, kn, ki = jax.random.split(jax.random.key(7), 3)
    centers = 8.0 * jax.random.normal(kc, (k_p, d_p), jnp.float32)
    ids = jnp.sort(jnp.arange(n_p) % k_p)       # block-coherent clusters
    xs = centers[ids] + 2.0 * jax.random.normal(kn, (n_p, d_p), jnp.float32)
    init_p = centers + 6.0 * jax.random.normal(ki, (k_p, d_p), jnp.float32)
    exact_fn = jax.jit(lambda x, c: ops.lloyd_solve_resident(
        x, c, max_iters=prune_iters, tol=0.0))
    pruned_fn = jax.jit(lambda x, c: ops.lloyd_solve_resident(
        x, c, max_iters=prune_iters, tol=0.0, prune="bounds",
        bound_block=bound_block, return_skips=True))
    t_exact = timeit(lambda x, c: exact_fn(x, c)[0], xs, init_p)
    t_pruned = timeit(lambda x, c: pruned_fn(x, c)[0], xs, init_p)
    exact_out = exact_fn(xs, init_p)
    pruned_out = pruned_fn(xs, init_p)
    bitwise_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(exact_out, pruned_out[:4]))
    iters_run = int(exact_out[2])
    skips = np.asarray(pruned_out[4])[:iters_run]
    skip_frac = [round(float(s) / t, 4) if t else 0.0 for s, t in skips]
    pruned_row = {
        "n": n_p, "d": d_p, "k": k_p,
        "mode": "interpret-pruned-vs-exact-resident",
        "solve_iters": iters_run, "bound_block": bound_block,
        "exact_solve_us": t_exact * 1e6,
        "pruned_solve_us": t_pruned * 1e6,
        "bitwise_equal": bitwise_equal,
        "skip_fraction_by_iter": skip_frac,
        "blocks_skipped_total": int(skips[:, 0].sum()),
        "blocks_total": int(skips[:, 1].sum()),
        "prune_vmem_bytes": resident_vmem_bytes(n_p, d_p, k_p,
                                                prune="bounds"),
        "exact_vmem_bytes": resident_vmem_bytes(n_p, d_p, k_p),
    }
    rows.append(pruned_row)

    # tuned vs default geometry: the fused step under the cache's winner for
    # this shape (specs.DEFAULT_SPEC on a cache miss — the tuned engine's
    # fallback) head-to-head with the default spec.  Run
    # `python -m repro.launch.autotune` first to populate the cache; without
    # it this row documents that tuned == default.
    n, d, k = SIZES[0]
    tuned_spec = (tuning.lookup_spec(n, d, k, jnp.float32)
                  or specs.DEFAULT_SPEC)
    # t_fus above already timed the default spec on this exact (x, c) —
    # reuse it, and only pay a second interpret-mode sweep when the cache
    # actually produced a different geometry
    t_def = t_fus
    t_tun = t_def if tuned_spec == specs.DEFAULT_SPEC else timeit(
        jax.jit(lambda x, c: ops.lloyd_step_fused(
            x, c, spec=tuned_spec, interpret=True)), x, c)
    tuned_row = {
        "n": n, "d": d, "k": k, "mode": "interpret-tuned-vs-default",
        "tuned_from_cache": tuned_spec != specs.DEFAULT_SPEC,
        "default_spec": specs.DEFAULT_SPEC.to_json(),
        "tuned_spec": tuned_spec.to_json(),
        "default_us": t_def * 1e6,
        "tuned_us": t_tun * 1e6,
        "default_vmem_bytes": specs.DEFAULT_SPEC.fused_vmem_bytes(n, d, k),
        "tuned_vmem_bytes": tuned_spec.fused_vmem_bytes(n, d, k),
    }
    rows.append(tuned_row)

    # k-means|| seeding vs the paper's sample baseline, END TO END (init +
    # Lloyd to convergence), same data and same key per trial.  This is the
    # init subsystem's quality contract: final SSE no worse AND strictly
    # fewer median Lloyd iterations — for the resident/batched megakernels,
    # iterations are on-chip while-loop trips per launch, so the init rounds
    # buy back whole sweeps of the convergence loop.  Per-seed stats run the
    # jnp engine over ref-backend seeds (the ref sweep is bitwise-identical
    # to the kernel sweep — tests/test_init.py holds that parity), keeping
    # the median cheap; the timed rows then run the real kernel path once:
    # fused init sweeps + resident solve under ops' default interpret policy.
    from repro.core.init import kmeans_parallel_init, sample_init
    n_i, d_i, k_i = 2048, 8, 8
    init_seeds = [3, 5, 7, 11, 13]
    cap_iters = 100
    kc_i, kn_i = jax.random.split(jax.random.key(17))
    centers_i = 10.0 * jax.random.normal(kc_i, (k_i, d_i), jnp.float32)
    xs_i = (centers_i[jnp.arange(n_i) % k_i]
            + jax.random.normal(kn_i, (n_i, d_i), jnp.float32))
    jnp_solve = jax.jit(lambda x, c: get_engine("jnp").solve(
        x, c, max_iters=cap_iters, tol=1e-6))
    trials = {"kmeanspar": {"sse": [], "iters": []},
              "sample": {"sse": [], "iters": []}}
    for s in init_seeds:
        key_s = jax.random.key(s)
        for name, c0 in (
                ("kmeanspar", kmeans_parallel_init(xs_i, key_s, k_i,
                                                   backend="ref")),
                ("sample", sample_init(xs_i, key_s, k_i))):
            _, sse_v, it_v, _ = jnp_solve(xs_i, c0)
            trials[name]["sse"].append(float(sse_v))
            trials[name]["iters"].append(int(it_v))
    med_it_par = float(np.median(trials["kmeanspar"]["iters"]))
    med_it_smp = float(np.median(trials["sample"]["iters"]))
    med_sse_par = float(np.median(trials["kmeanspar"]["sse"]))
    med_sse_smp = float(np.median(trials["sample"]["sse"]))
    key_t = jax.random.key(init_seeds[0])
    res_solve = jax.jit(lambda x, c: ops.lloyd_solve_resident(
        x, c, max_iters=cap_iters, tol=1e-6)[0])
    t_par = timeit(lambda: res_solve(
        xs_i, kmeans_parallel_init(xs_i, key_t, k_i)), repeats=1)
    t_smp = timeit(lambda: res_solve(xs_i, sample_init(xs_i, key_t, k_i)),
                   repeats=1)
    init_row = {
        "n": n_i, "d": d_i, "k": k_i,
        "mode": "interpret-kmeanspar-vs-sample-init",
        "seeds": init_seeds, "ell": 2.0 * k_i, "max_iters": cap_iters,
        "kmeanspar_sse": trials["kmeanspar"]["sse"],
        "sample_sse": trials["sample"]["sse"],
        "kmeanspar_iters": trials["kmeanspar"]["iters"],
        "sample_iters": trials["sample"]["iters"],
        "kmeanspar_median_iters": med_it_par,
        "sample_median_iters": med_it_smp,
        "kmeanspar_median_sse": med_sse_par,
        "sample_median_sse": med_sse_smp,
        "sse_not_worse": med_sse_par <= med_sse_smp,
        "fewer_median_iters": med_it_par < med_it_smp,
        "kmeanspar_e2e_us": t_par * 1e6,
        "sample_e2e_us": t_smp * 1e6,
        "init_vmem_bytes": specs.DEFAULT_SPEC.init_vmem_bytes(
            n_i, d_i, max(8, 2 * k_i)),
    }
    rows.append(init_row)

    # cross-pod DCN pricing: exact vs int8ef reduction traffic for the
    # multi-pod S2, priced with the io_model alongside the HBM models above.
    # Analytic (no devices needed): per-pod payload per Lloyd iteration and
    # whole-solve ring-all-reduce bytes at the dist_bench geometry plus the
    # dryrun production shape — the ratio is shape-dependent ((k*d + 5k + 4)
    # / (4k*(d+1))), dropping under 1/3 once d >= 16, which is the paper's
    # 2/3-lower-I/O headline restated for the pod axis.
    from repro.core.io_model import (dcn_reduce_bytes_ipkmeans,
                                     ipkmeans_stats_payload_bytes)
    dcn_rows = []
    for m_x, k_x, d_x, pods_x, iters_x, tag in (
            (16, 8, 32, 2, NOMINAL_ITERS, "dist-bench-shape"),
            (4096, 1024, 64, 2, NOMINAL_ITERS, "production-shape")):
        ex_b = ipkmeans_stats_payload_bytes(m_x, k_x, d_x, "exact")
        q_b = ipkmeans_stats_payload_bytes(m_x, k_x, d_x, "int8ef")
        dcn_rows.append({
            "m": m_x, "k": k_x, "d": d_x, "pods": pods_x, "iters": iters_x,
            "mode": "dcn-exact-vs-int8ef", "shape_tag": tag,
            "payload_bytes_exact": ex_b,
            "payload_bytes_int8ef": q_b,
            "payload_ratio": q_b / ex_b,
            "dcn_bytes_solve_exact": dcn_reduce_bytes_ipkmeans(
                m_x, k_x, d_x, iters_x, pods_x, "exact"),
            "dcn_bytes_solve_int8ef": dcn_reduce_bytes_ipkmeans(
                m_x, k_x, d_x, iters_x, pods_x, "int8ef"),
        })
    rows.extend(dcn_rows)

    record("kernel_bench", rows,
           ("kernel_assign", f"{assign_row['jnp_ref_us']:.0f}",
            f"gflops={assign_row['gflops_per_s']:.1f}"))
    record("kernel_bench", rows,
           ("kernel_fused_vs_two", f"{fused_row['pallas_fused_us']:.0f}",
            f"hbm_ratio={fused_row['fused_hbm_ratio']:.2f}"))
    record("kernel_bench", rows,
           ("kernel_resident_vs_fused",
            f"{resident_row['resident_solve_us']:.0f}",
            f"solve_hbm_ratio={resident_row['resident_solve_hbm_ratio']:.2f}"))
    record("kernel_bench", rows,
           ("kernel_batched_vs_vmap",
            f"{batched_row['batched_stack_us']:.0f}",
            f"launches={batched_row['launches_batched']}/"
            f"{batched_row['launches_vmap_resident']}"))
    record("kernel_bench", rows,
           ("kernel_reseed_batched_vs_fallback",
            f"{reseed_row['batched_reseed_stack_us']:.0f}",
            f"launches={reseed_row['launches_batched_reseed']}/"
            f"{reseed_row['launches_old_fallback']}"))
    record("kernel_bench", rows,
           ("kernel_pruned_vs_exact",
            f"{pruned_row['pruned_solve_us']:.0f}",
            f"bitwise={pruned_row['bitwise_equal']} "
            f"skip_last={pruned_row['skip_fraction_by_iter'][-1]:.2f}"))
    record("kernel_bench", rows,
           ("kernel_tuned_vs_default", f"{tuned_row['tuned_us']:.0f}",
            f"from_cache={tuned_row['tuned_from_cache']}"))
    record("kernel_bench", rows,
           ("kernel_init_kmeanspar_vs_sample",
            f"{init_row['kmeanspar_e2e_us']:.0f}",
            f"median_iters={init_row['kmeanspar_median_iters']:.0f}/"
            f"{init_row['sample_median_iters']:.0f} "
            f"sse_ok={init_row['sse_not_worse']}"))
    record("kernel_bench", rows,
           ("kernel_dcn_exact_vs_int8ef", "0",
            f"payload_ratio={dcn_rows[0]['payload_ratio']:.3f}"))
    return rows


if __name__ == "__main__":
    run()
