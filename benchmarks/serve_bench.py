"""Serving-tier bench: dispatch latency/QPS per bucket + refresh quality.

Times the :class:`repro.core.serve.NearestCentroidServer` query path the way
traffic sees it — submit, coalesce, pad to the bucket, one fused assign
kernel, unpad — and reports p50/p99 latency and QPS per batch-size bucket,
plus a bucket-policy comparison (pow2 ladder vs a two-rung fixed ladder) on
the same mixed-size request stream.  On this CPU container the kernel runs
under interpret=True, so absolute numbers are correctness-path timings; the
structural outputs (trace counts, bucket ladders, relative bucket scaling)
are the portable part.

The refresh-quality row answers the serving tier's core accuracy question:
on a drifting stream, how close does Sculley mini-batch refresh
(``engine.update_minibatch``, one fused sweep per batch) track the full
re-solve it replaces — and how much better is it than not refreshing at
all?  Reported as SSE of the final (most-drifted) batch under stale /
mini-batch-refreshed / full-resolve centroids.

``benchmarks.run`` snapshots these rows to ``BENCH_serve.json`` at the repo
root (refusing the snapshot if the reference bucket's p99 regresses — see
run.py), so serving perf accumulates commit over commit like BENCH_kernel.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record
from repro.core import KMeansParams, kmeans
from repro.core.serve import BucketPolicy, NearestCentroidServer
from repro.kernels import ref
from repro.launch.serve_kmeans import make_stream

D, K = 16, 32
LAT_BUCKETS = (16, 64, 256)       # >= 3 buckets; 64 is the reference
REFERENCE_BUCKET = 64
REPEATS = 7


def _latencies(fn, *args, repeats: int = REPEATS):
    """Per-call wall seconds (block_until_ready), after one warmup."""
    jax.block_until_ready(fn(*args))
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        out.append(time.perf_counter() - t0)
    return out


def _sse_on(points, centroids) -> float:
    _, mind = ref.assign_ref(points, centroids)
    return float(jnp.sum(mind))


def _seed_server(policy: BucketPolicy) -> NearestCentroidServer:
    data, _ = make_stream(jax.random.key(0), 8 * K, D, K)
    res = kmeans(data, data[:K], params=KMeansParams(max_iters=10))
    return NearestCentroidServer(res.centroids, policy=policy)


def _latency_rows():
    server = _seed_server(BucketPolicy(min_bucket=8,
                                       max_bucket=max(LAT_BUCKETS)))
    rows = []
    for bucket in LAT_BUCKETS:
        q, _ = make_stream(jax.random.key(bucket), bucket, D, K)
        lats = np.asarray(_latencies(server.assign, q)) * 1e3
        p50, p99 = np.percentile(lats, 50), np.percentile(lats, 99)
        rows.append({
            "mode": "latency",
            "bucket": int(bucket),
            "d": D, "k": K,
            "p50_ms": round(float(p50), 3),
            "p99_ms": round(float(p99), 3),
            "qps": round(bucket / (float(p50) * 1e-3), 1),
            "reference_bucket": bucket == REFERENCE_BUCKET,
        })
        print(f"serve_bench,{p50 * 1e3:.0f},bucket{bucket}_p50_us",
              flush=True)
    assert all(v == 1 for v in server.trace_counts.values()), \
        server.trace_counts
    return rows


def _policy_rows():
    """Same mixed-size stream under pow2 vs a two-rung fixed ladder: the
    fixed ladder trades pad waste for fewer compiled buckets."""
    sizes = [3, 40, 9, 120, 7, 64, 25, 200, 5, 90]
    policies = {
        "pow2": BucketPolicy(min_bucket=8, max_bucket=256),
        "fixed2": BucketPolicy(kind="fixed", ladder=(64, 256)),
    }
    rows = []
    for name, pol in policies.items():
        server = _seed_server(pol)
        queries = [make_stream(jax.random.key(100 + n), n, D, K)[0]
                   for n in sizes]
        for q in queries:          # compile pass: buckets trace once here
            server.assign(q)
        t0 = time.perf_counter()
        for q in queries:
            jax.block_until_ready(server.assign(q))
        wall = time.perf_counter() - t0
        pad = sum(pol.bucket_for(n) - n for n in sizes)
        rows.append({
            "mode": "bucket-policy",
            "policy": name,
            "buckets_compiled": len(server.trace_counts),
            "pad_rows": int(pad),
            "stream_rows": int(sum(sizes)),
            "stream_ms": round(wall * 1e3, 2),
        })
        print(f"serve_bench,{wall * 1e6 / len(sizes):.0f},"
              f"policy_{name}_us_per_req", flush=True)
    return rows


def _refresh_row():
    """Drifting stream: mini-batch-refreshed vs stale vs full-resolve
    centroids, scored on the final (most drifted) batch."""
    rounds, rows_per, drift_step = 5, 192, 0.5
    server = _seed_server(BucketPolicy())
    stale = server.centroids
    batches = []
    for r in range(rounds):
        batch, _ = make_stream(jax.random.key(500 + r), rows_per, D, K,
                               drift=(r + 1) * drift_step)
        batches.append(batch)
        server.refresh(batch)
    final = batches[-1]
    full = kmeans(jnp.concatenate(batches), stale,
                  params=KMeansParams(max_iters=30))
    sse_stale = _sse_on(final, stale)
    sse_mb = _sse_on(final, server.centroids)
    sse_full = _sse_on(final, full.centroids)
    row = {
        "mode": "refresh-quality",
        "rounds": rounds, "rows_per_round": rows_per,
        "drift_per_round": drift_step,
        "sse_stale": round(sse_stale, 2),
        "sse_minibatch": round(sse_mb, 2),
        "sse_full_resolve": round(sse_full, 2),
        "refresh_sse_series": [round(s, 1) for s in server.refresh_sse],
        "refreshed_not_worse": bool(sse_mb <= sse_stale * 1.001),
        "vs_full_ratio": round(sse_mb / max(sse_full, 1e-9), 3),
    }
    print(f"serve_bench,0,refresh_mb_over_full_"
          f"{row['vs_full_ratio']}", flush=True)
    return [row]


def run():
    rows = _latency_rows() + _policy_rows() + _refresh_row()
    return record("serve_bench", rows)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="same sizes — the bench is already CI-scale; the "
                         "flag mirrors the other harness entry points")
    ap.parse_args(argv)
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
