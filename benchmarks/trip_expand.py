"""Trip-expand compiled cost terms for scanned-layer models.

XLA's cost_analysis counts a while/scan body ONCE (verified in
tests/test_dryrun_accounting.py), so for a model whose layers run under
``lax.scan`` the measured FLOPs/bytes are

    measured = outside + sum_g body_g            (g = scan groups)

while a step really executes

    true     = outside + sum_g L_g * body_g.

The collective term is already exact (the HLO parser multiplies
known_trip_count).  This post-processor expands compute/memory:

  * ``outside`` (embedding + logits + loss) is computed analytically per
    cell — 2*T*d*V fwd (x3 for train) — and subtracted;
  * the remaining body total is split across scan groups in proportion to
    per-group parameter counts (exact for FLOPs of param-bound steps; an
    estimate for attention-quadratic prefill cells, noted per record);
  * unrolled groups (count==1: xlstm, griffin tails) are already exact and
    get multiplier 1.

Writes ``roofline_expanded`` + ``flops_expanded``/``bytes_expanded`` into
each experiments/dryrun JSON (idempotent).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES
from repro.launch.dryrun import HBM_BW, PEAK_FLOPS, ICI_BW
from repro.models import registry, transformer

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def group_param_counts(cfg):
    """[(L_g, params_per_layer_g, scanned?)] per layer group."""
    import jax
    boxed = registry.abstract_params(cfg)
    groups = transformer.layer_groups(cfg) if not cfg.is_encdec else [
        ("enc", cfg.encoder_layers), ("dec", cfg.num_layers)]
    out = []
    if cfg.is_encdec:
        import numpy as np
        params = boxed
        for key, count in (("enc_layers", cfg.encoder_layers),
                           ("dec_layers", cfg.num_layers)):
            n = sum(int(np_prod(l.shape)) // count
                    for l in jax.tree.leaves(params[key]))
            out.append((count, n, True))
        return out
    for gi, (kind, count) in enumerate(groups):
        gp = boxed["groups"][gi]
        n = sum(int(np_prod(l.shape)) for l in jax.tree.leaves(gp))
        if count > 1:
            n //= count
        out.append((count, n, count > 1))
    return out


def np_prod(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def outside_flops(cfg, shape) -> float:
    """Embedding+logits+loss flops per device (analytic)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    fwd = 2.0 * tokens * cfg.d_model * cfg.vocab_size
    mult = 3.0 if shape.kind == "train" else 1.0
    return fwd * mult / 256.0


def expand_record(rec: dict) -> dict:
    if rec.get("status") != "ok" or rec["arch"].startswith("kmeans"):
        return rec
    cfg = ARCHS.get(rec["arch"])
    if cfg is None:
        return rec
    if rec.get("variant"):  # variants may carry config overrides
        import dataclasses
        if rec["variant"].startswith(("A", "M")):
            pass  # dispatch/remat overrides don't change param layout
    shape = SHAPES[rec["shape"]]
    groups = group_param_counts(cfg)
    scanned = [(L, w) for (L, w, s) in groups if s]
    unrolled_w = sum(w * L for (L, w, s) in groups if not s)
    if not scanned:
        rec["flops_expanded"] = rec["flops"]
        rec["bytes_expanded"] = rec["bytes_accessed"]
        factor = 1.0
    else:
        out_f = outside_flops(cfg, shape)
        w_tot = sum(w for (_, w) in scanned) + unrolled_w
        body_meas_f = max(rec["flops"] - out_f, 0.0)
        body_meas_b = rec["bytes_accessed"]          # outside bytes ~ small
        # split measured body across groups by param weight; expand by L_g
        exp_f = out_f
        exp_b = 0.0
        for (L, w) in scanned:
            share = w / w_tot
            exp_f += body_meas_f * share * L
            exp_b += body_meas_b * share * L
        # unrolled groups already counted exactly
        share_u = unrolled_w / w_tot
        exp_f += body_meas_f * share_u
        exp_b += body_meas_b * share_u
        rec["flops_expanded"] = exp_f
        rec["bytes_expanded"] = exp_b
        factor = exp_f / rec["flops"] if rec["flops"] else 1.0
    total_coll = sum(rec.get("collective_bytes", {}).values())
    rec["trip_expansion_factor"] = round(factor, 2)
    rec["roofline_expanded"] = {
        "compute_s": rec["flops_expanded"] / PEAK_FLOPS,
        "memory_s": rec["bytes_expanded"] / HBM_BW,
        "collective_s": total_coll / ICI_BW,
    }
    rec["roofline_expanded"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"),
        key=rec["roofline_expanded"].get)
    return rec


def main():
    for p in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(p.read_text())
        rec = expand_record(rec)
        p.write_text(json.dumps(rec, indent=2))
    print("expanded", len(list(DRYRUN.glob('*.json'))), "records")


if __name__ == "__main__":
    main()
