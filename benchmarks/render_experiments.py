"""Render EXPERIMENTS.md tables from experiments/{dryrun,bench} JSONs.

    PYTHONPATH=src python -m benchmarks.render_experiments > /tmp/tables.md

Keeps EXPERIMENTS.md numbers reproducible from artifacts.
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1] / "experiments"


def _fmt(x, n=3):
    return f"{x:.{n}e}" if isinstance(x, float) else str(x)


def roofline_table(mesh: str):
    rows = []
    for p in sorted((ROOT / "dryrun").glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("variant"):
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"skip: {r['reason'][:58]} | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        rf = r.get("roofline_expanded", r["roofline"])
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / bound if bound else 0
        flops = r.get("flops_expanded", r.get("flops"))
        useful = (r.get("model_flops_per_device", 0) / flops
                  if flops else 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(rf['compute_s'])} | "
            f"{_fmt(rf['memory_s'])} | {_fmt(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s', '')} | {frac:.3f} | {useful:.2f} |")
    head = ("| arch | shape | compute_s | memory_s | collective_s | "
            "dominant | roofline frac | useful-FLOPs ratio |\n"
            "|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def dryrun_table(mesh: str):
    rows = []
    for p in sorted((ROOT / "dryrun").glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("variant") or r["status"] != "ok":
            continue
        args = r.get("argument_size_in_bytes", 0) / 2**30
        temp = r.get("temp_size_in_bytes", 0) / 2**30
        coll = sum(r.get("collective_bytes", {}).values()) / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['flops']:.2e} | "
            f"{r['bytes_accessed']:.2e} | {coll:.2f} | {args:.2f} | "
            f"{temp:.2f} | {r.get('compile_s', 0):.0f}s |")
    head = ("| arch | shape | HLO FLOPs/dev | HLO bytes/dev | coll GiB/dev | "
            "args GiB | temps GiB | compile |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def variant_table():
    rows = []
    for p in sorted((ROOT / "dryrun").glob("*.json")):
        r = json.loads(p.read_text())
        tag = r.get("variant")
        if not tag or r["status"] != "ok":
            continue
        rf = r.get("roofline_expanded", r["roofline"])
        rows.append(f"| {tag} | {r['arch']} x {r['shape']} | "
                    f"{_fmt(rf['compute_s'])} | {_fmt(rf['memory_s'])} | "
                    f"{_fmt(rf['collective_s'])} | {r.get('note', '')[:70]} |")
    head = ("| variant | cell | compute_s | memory_s | collective_s | note |\n"
            "|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def kmeans_table(mesh: str):
    rows = []
    for p in sorted((ROOT / "dryrun").glob(f"kmeans-*__{mesh}.json")):
        r = json.loads(p.read_text())
        rf = r["roofline"]
        extra = []
        if "collectives_in_solver_loop" in r:
            extra.append(f"loop-collectives={r['collectives_in_solver_loop']}")
        rows.append(f"| {r['arch']} | {_fmt(rf['compute_s'])} | "
                    f"{_fmt(rf['memory_s'])} | {_fmt(rf['collective_s'])} | "
                    f"{rf['dominant'].replace('_s','')} | "
                    f"{'; '.join(extra) or '—'} |")
    head = ("| program | compute_s | memory_s | collective_s | dominant | "
            "notes |\n|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def bench_tables():
    out = []
    for name in ("table1_sse", "fig5_io", "fig6_time", "table2_reducers",
                 "table3_large", "fig8_variants"):
        p = ROOT / "bench" / f"{name}.json"
        if not p.exists():
            continue
        rows = json.loads(p.read_text())
        if not rows:
            continue
        keys = list(rows[0].keys())
        head = "| " + " | ".join(keys) + " |\n|" + "---|" * len(keys)
        body = "\n".join(
            "| " + " | ".join(
                (f"{v:.4g}" if isinstance(v, float) else str(v))
                for v in r.values()) + " |"
            for r in rows)
        out.append(f"### {name}\n\n{head}\n{body}")
    return "\n\n".join(out)


def main():
    print("## §Roofline — single pod 16x16 (256 chips)\n")
    print(roofline_table("16x16"))
    print("\n## §Dry-run raw terms — 16x16\n")
    print(dryrun_table("16x16"))
    print("\n## §Dry-run — multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table("2x16x16"))
    print("\n## k-means programs (the paper's technique) — 16x16\n")
    print(kmeans_table("16x16"))
    print("\n## §Perf variants\n")
    print(variant_table())
    print("\n## Paper-claim benchmarks\n")
    print(bench_tables())


if __name__ == "__main__":
    main()
