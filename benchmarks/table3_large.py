"""Paper Table 3: 15000 points, 4 clusters, 58/117/234/468/937 reducers.

Claims: first four experiments nearly match single-machine SSE (1.3178e5);
937 reducers (15 pts/reducer) degrades but still clusters."""
from __future__ import annotations

import jax

from benchmarks.common import record, timeit
from repro.core import IPKMeansConfig, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_15000

REDUCERS = (58, 117, 234, 468, 937)


def run():
    pts, _ = paper_dataset_15000(1)
    init = initial_centroid_groups(pts, 4, groups=1, seed=200)[0]
    base = float(pkmeans(pts, init).sse)
    rows = []
    for m in REDUCERS:
        cfg = IPKMeansConfig(num_clusters=4, num_subsets=m)
        res = ipkmeans(pts, init, jax.random.key(0), cfg)
        t = timeit(lambda cfg=cfg: ipkmeans(pts, init, jax.random.key(0),
                                            cfg), repeats=1)
        rows.append({
            "reducers": m,
            "sse": float(res.sse),
            "sse_vs_single_machine_pct": 100 * (float(res.sse) / base - 1),
            "jax_sec": t,
            "points_per_reducer": 15000 // m,
        })
    ok4 = all(r["sse_vs_single_machine_pct"] < 10 for r in rows[:4])
    record("table3_large", rows,
           ("table3_large", f"{rows[0]['jax_sec']*1e6:.0f}",
            f"first4_within_10pct={ok4}"))
    return rows


if __name__ == "__main__":
    run()
