"""Paper Table 2: reducer (subset) scaling on dataset 1 — 6/11/23/46/93
reducers.  Claims: runtime falls with more reducers (parallel efficiency),
SSE degrades mildly (~6.5% at 93)."""
from __future__ import annotations

import jax

from benchmarks.common import record, timeit
from repro.core import IPKMeansConfig, io_model, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_3000

REDUCERS = (6, 11, 23, 46, 93)


def run():
    pts, _ = paper_dataset_3000(0)
    init = initial_centroid_groups(pts, 5, groups=1)[0]
    base = float(pkmeans(pts, init).sse)
    model = io_model.HadoopCostModel()
    rows = []
    for m in REDUCERS:
        cfg = IPKMeansConfig(num_clusters=5, num_subsets=m)
        res = ipkmeans(pts, init, jax.random.key(0), cfg)
        t = timeit(lambda cfg=cfg: ipkmeans(pts, init, jax.random.key(0),
                                            cfg))
        # modeled Hadoop time: reducer critical path shrinks with subsets
        # (each reducer clusters n/m points); kd depth fixed by capacity
        h = model.ipkmeans_sec(3000, 2, 5, m, int(res.kd_depth),
                               reducer_sec=0.001 * 3000 / m
                               * float(res.subset_iters.max()))
        rows.append({
            "reducers": m,
            "sse": float(res.sse),
            "sse_vs_single_machine_pct": 100 * (float(res.sse) / base - 1),
            "jax_sec": t,
            "hadoop_model_sec": h,
            "max_subset_iters": int(res.subset_iters.max()),
        })
    drift = rows[-1]["sse_vs_single_machine_pct"]
    record("table2_reducers", rows,
           ("table2_reducers", f"{rows[0]['jax_sec']*1e6:.0f}",
            f"sse_drift_at_93={drift:.2f}pct"))
    return rows


if __name__ == "__main__":
    run()
