"""Paper Table 1: SSE of PKMeans vs IPKMeans — 3000 pts, K=5, 5 initial
centroid groups.  Claim: SSEs are very close (paper: 3.4817e4 vs 3.484xe4,
a <0.1% gap).

Rider rows exercise the init axis on the same table: the pipeline deriving
its own seeds (``cfg.with_init``) — k-means|| vs plain sampling, same key —
reporting final SSE and the median per-reducer Lloyd iteration count the
better seeds buy back."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import record, timeit
from repro.core import IPKMeansConfig, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_3000


def run():
    pts, _ = paper_dataset_3000(0)
    inits = initial_centroid_groups(pts, 5, groups=5)
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    rows = []
    for i, init in enumerate(inits):
        ref = pkmeans(pts, init)
        res = ipkmeans(pts, init, jax.random.key(0), cfg)
        rows.append({
            "experiment": i + 1,
            "sse_pkmeans": float(ref.sse),
            "sse_ipkmeans": float(res.sse),
            "gap_pct": 100 * (float(res.sse) / float(ref.sse) - 1),
            "pkmeans_iters": int(ref.iters),
            "ipkmeans_kd_depth": int(res.kd_depth),
        })
    worst = max(r["gap_pct"] for r in rows)
    # init axis: same pipeline, seeds derived from the key instead of the
    # paper's externally fixed groups (kmeans|| rounds run the fused init
    # sweeps; "sample" is the paper-style baseline)
    init_stats = {}
    for strategy in ("sample", "kmeans||"):
        res = ipkmeans(pts, None, jax.random.key(0), cfg.with_init(strategy))
        med_iters = float(np.median(np.asarray(res.subset_iters)))
        init_stats[strategy] = (float(res.sse), med_iters)
        rows.append({
            "experiment": f"init:{strategy}",
            "sse_ipkmeans": float(res.sse),
            "median_subset_iters": med_iters,
            "ipkmeans_kd_depth": int(res.kd_depth),
        })
    t = timeit(lambda: ipkmeans(pts, inits[0], jax.random.key(0), cfg))
    record("table1_sse", rows,
           ("table1_sse", f"{t*1e6:.0f}", f"worst_gap_pct={worst:.3f}"))
    record("table1_sse", rows,
           ("table1_init_kmeanspar_vs_sample", f"{t*1e6:.0f}",
            f"sse={init_stats['kmeans||'][0]:.0f}/"
            f"{init_stats['sample'][0]:.0f} "
            f"median_iters={init_stats['kmeans||'][1]:.0f}/"
            f"{init_stats['sample'][1]:.0f}"))
    return rows


if __name__ == "__main__":
    run()
