"""Paper Table 1: SSE of PKMeans vs IPKMeans — 3000 pts, K=5, 5 initial
centroid groups.  Claim: SSEs are very close (paper: 3.4817e4 vs 3.484xe4,
a <0.1% gap)."""
from __future__ import annotations

import jax

from benchmarks.common import record, timeit
from repro.core import IPKMeansConfig, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_3000


def run():
    pts, _ = paper_dataset_3000(0)
    inits = initial_centroid_groups(pts, 5, groups=5)
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    rows = []
    for i, init in enumerate(inits):
        ref = pkmeans(pts, init)
        res = ipkmeans(pts, init, jax.random.key(0), cfg)
        rows.append({
            "experiment": i + 1,
            "sse_pkmeans": float(ref.sse),
            "sse_ipkmeans": float(res.sse),
            "gap_pct": 100 * (float(res.sse) / float(ref.sse) - 1),
            "pkmeans_iters": int(ref.iters),
            "ipkmeans_kd_depth": int(res.kd_depth),
        })
    worst = max(r["gap_pct"] for r in rows)
    t = timeit(lambda: ipkmeans(pts, inits[0], jax.random.key(0), cfg))
    record("table1_sse", rows,
           ("table1_sse", f"{t*1e6:.0f}", f"worst_gap_pct={worst:.3f}"))
    return rows


if __name__ == "__main__":
    run()
