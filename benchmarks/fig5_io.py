"""Paper Fig 5: disk I/O bytes of IPKMeans vs PKMeans over the 5 experiments.

Byte counters come from the calibrated Hadoop cost model fed with *measured*
iteration counts from our JAX runs; the TPU-native restatement (ICI
collective bytes) is reported alongside.  Claim: up to 2/3 lower I/O."""
from __future__ import annotations

import jax

from benchmarks.common import record
from repro.core import IPKMeansConfig, io_model, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_3000


def run():
    pts, _ = paper_dataset_3000(0)
    inits = initial_centroid_groups(pts, 5, groups=5)
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    model = io_model.HadoopCostModel()
    n, d, k, m = 3000, 2, 5, 6
    rows = []
    for i, init in enumerate(inits):
        ref = pkmeans(pts, init)
        res = ipkmeans(pts, init, jax.random.key(0), cfg)
        pk = model.pkmeans_bytes(n, d, k, int(ref.iters))
        ipk = model.ipkmeans_bytes(n, d, k, m, int(res.kd_depth))
        pk_total = pk["read"] + pk["write"]
        ipk_total = ipk["read"] + ipk["write"]
        rows.append({
            "experiment": i + 1,
            "pkmeans_bytes": pk_total, "pkmeans_jobs": pk["jobs"],
            "ipkmeans_bytes": ipk_total, "ipkmeans_jobs": ipk["jobs"],
            "io_reduction": 1 - ipk_total / pk_total,
            "tpu_coll_bytes_pkmeans": io_model.tpu_collective_bytes_pkmeans(
                d, k, int(ref.iters), 256),
            "tpu_coll_bytes_ipkmeans": io_model.tpu_collective_bytes_ipkmeans(
                n, d, k, m, int(res.kd_depth), 256),
        })
    best = max(r["io_reduction"] for r in rows)
    record("fig5_io", rows,
           ("fig5_io", "0", f"best_io_reduction={best:.3f}"))
    return rows


if __name__ == "__main__":
    run()
