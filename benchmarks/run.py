"""Benchmark harness — one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines; full rows land in
experiments/bench/*.json, and a successful kernel_bench additionally
snapshots to ``BENCH_kernel.json`` at the repo root so the kernel perf
trajectory accumulates commit over commit (CI's ``--smoke`` writes it too).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: per-bench failures become warnings and "
                         "the exit code stays 0 — only a harness crash "
                         "(anything escaping the per-bench guard) fails")
    args = ap.parse_args()
    from benchmarks import (dist_bench, fig5_io, fig6_time, fig8_variants,
                            kernel_bench, roofline, serve_bench, table1_sse,
                            table2_reducers, table3_large)
    benches = [
        ("table1_sse", table1_sse.run),
        ("fig5_io", fig5_io.run),
        ("fig6_time", fig6_time.run),
        ("table2_reducers", table2_reducers.run),
        ("table3_large", table3_large.run),
        ("fig8_variants", fig8_variants.run),
        ("kernel_bench", kernel_bench.run),
        ("serve_bench", serve_bench.run),
        ("dist_bench", dist_bench.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        try:
            rows = fn()
            if name == "kernel_bench":
                # repo-root snapshot: the perf-trajectory artifact.  The
                # pruned-vs-exact row's skip-fraction trace is part of the
                # contract the snapshot tracks — refuse to write one without
                # it (a silently vanished row would read as "still covered").
                pruned = [r for r in rows if r.get("mode")
                          == "interpret-pruned-vs-exact-resident"]
                if not pruned or not pruned[0].get("skip_fraction_by_iter"):
                    raise RuntimeError(
                        "kernel_bench rows lack the pruned-vs-exact "
                        "skip-fraction columns; snapshot not written")
                if not pruned[0].get("bitwise_equal"):
                    raise RuntimeError(
                        "pruned-vs-exact row reports bitwise_equal=False")
                # likewise the init row: the k-means|| quality contract
                # (SSE no worse, strictly fewer median Lloyd iterations
                # than sample seeding, same data/key) is part of what the
                # snapshot certifies commit over commit.
                init_r = [r for r in rows if r.get("mode")
                          == "interpret-kmeanspar-vs-sample-init"]
                if not init_r:
                    raise RuntimeError(
                        "kernel_bench rows lack the kmeans||-vs-sample init "
                        "row; snapshot not written")
                if not (init_r[0].get("sse_not_worse")
                        and init_r[0].get("fewer_median_iters")):
                    raise RuntimeError(
                        "kmeans|| init row fails its quality contract "
                        f"(sse_not_worse={init_r[0].get('sse_not_worse')}, "
                        f"fewer_median_iters="
                        f"{init_r[0].get('fewer_median_iters')})")
                (REPO_ROOT / "BENCH_kernel.json").write_text(
                    json.dumps(rows, indent=2) + "\n")
            if name == "serve_bench":
                # serving-tier snapshot, same contract style: the row set
                # must cover the latency ladder and the refresh-quality
                # check, and the reference bucket's p99 must not regress
                # against the committed snapshot — a slower hot path should
                # fail loudly, not silently rebase the trajectory.
                lat = [r for r in rows if r.get("mode") == "latency"]
                if len(lat) < 3 or any(
                        k not in r for r in lat
                        for k in ("p50_ms", "p99_ms", "qps")):
                    raise RuntimeError(
                        "serve_bench needs >=3 latency rows with p50/p99/"
                        "qps; snapshot not written")
                refr = [r for r in rows
                        if r.get("mode") == "refresh-quality"]
                if not refr or not refr[0].get("refreshed_not_worse"):
                    raise RuntimeError(
                        "serve_bench refresh-quality row missing or "
                        "reporting mini-batch refresh worse than stale "
                        "centroids; snapshot not written")
                ref_rows = [r for r in lat if r.get("reference_bucket")]
                if len(ref_rows) != 1:
                    raise RuntimeError(
                        "serve_bench needs exactly one reference_bucket "
                        "latency row; snapshot not written")
                snap = REPO_ROOT / "BENCH_serve.json"
                if snap.exists():
                    prev = [r for r in json.loads(snap.read_text())
                            if r.get("reference_bucket")]
                    # generous factor: interpret-mode timings on shared CI
                    # runners are noisy — this catches order-of-magnitude
                    # regressions, not jitter
                    if prev and ref_rows[0]["p99_ms"] > 5.0 * prev[0]["p99_ms"]:
                        raise RuntimeError(
                            f"serve_bench p99 at reference bucket "
                            f"{ref_rows[0]['bucket']} regressed: "
                            f"{ref_rows[0]['p99_ms']}ms vs snapshot "
                            f"{prev[0]['p99_ms']}ms; snapshot not written")
                snap.write_text(json.dumps(rows, indent=2) + "\n")
            if name == "dist_bench":
                # multi-pod snapshot: the pod-scaling table must contain
                # both reduce modes at >1 pod, the compressed payload must
                # honor the paper's 2/3-lower-I/O headline (int8ef <= 1/3
                # of exact), and the compressed solve must land within
                # 1e-3 relative SSE of the exact reduction on every mesh —
                # else the snapshot is not written.
                scal = [r for r in rows if r.get("mode") == "pod-scaling"]
                q = [r for r in scal
                     if r.get("reduce") == "int8ef" and r.get("pods", 0) > 1]
                ex = {r["pods"]: r for r in scal
                      if r.get("reduce") == "exact" and r.get("pods", 0) > 1}
                if not q or not ex:
                    raise RuntimeError(
                        "dist_bench rows lack multi-pod exact/int8ef pairs; "
                        "snapshot not written")
                for r in q:
                    cap = ex[r["pods"]]["payload_bytes_per_pod_per_iter"] / 3
                    if r["payload_bytes_per_pod_per_iter"] > cap:
                        raise RuntimeError(
                            f"int8ef payload {r['payload_bytes_per_pod_per_iter']}"
                            f" > exact/3 ({cap:.0f}) at pods={r['pods']}; "
                            f"snapshot not written")
                    if abs(r["sse_rel_delta_vs_exact"]) > 1e-3:
                        raise RuntimeError(
                            f"int8ef SSE off by {r['sse_rel_delta_vs_exact']:.2e}"
                            f" relative (> 1e-3) at pods={r['pods']}; "
                            f"snapshot not written")
                # S1 sharding: the sharded histogram partition must be
                # bit-identical to the single-device reference, and its
                # modeled DCN payload must undercut the dataset by >= 10x
                # (the summaries-not-data property of the radix build).
                s1 = [r for r in rows
                      if r.get("variant") == "sharded-histogram"]
                if not s1:
                    raise RuntimeError(
                        "dist_bench rows lack the s1-sharding "
                        "sharded-histogram row; snapshot not written")
                for r in s1:
                    if not (r["region_ids_exact"] and r["subset_ids_exact"]):
                        raise RuntimeError(
                            "sharded S1 ids diverge from the single-device "
                            "histogram reference; snapshot not written")
                    if r["s1_dcn_payload_bytes"] > r["points_bytes"] / 10:
                        raise RuntimeError(
                            f"sharded S1 DCN payload "
                            f"{r['s1_dcn_payload_bytes']} > points/10 "
                            f"({r['points_bytes'] / 10:.0f}); "
                            "snapshot not written")
                (REPO_ROOT / "BENCH_dist.json").write_text(
                    json.dumps(rows, indent=2) + "\n")
        except Exception:
            failed += 1
            print(f"{name},ERROR,{traceback.format_exc(limit=1).splitlines()[-1]}",
                  flush=True)
            if args.smoke:
                print(f"::warning::benchmark {name} failed (tolerated in "
                      f"--smoke mode)", flush=True)
    if failed and not args.smoke:
        sys.exit(1)


if __name__ == "__main__":
    main()
