"""§Roofline: render the three-term roofline table from the dry-run JSONs.

For the kmeans Lloyd cells the table also carries a *fused-kernel memory
projection*: ``memory_s_fused`` is the analytic per-device HBM time of one
fused-kernel iteration (``kernel_bench.lloyd_hbm_bytes(..., fused=True)``
over the device's shard), and ``fused_hbm_ratio`` is how much less traffic
that is than the two-kernel path's model (roughly 2x for the production
d=64 problem).  Both columns are analytic — the measured ``memory_s`` comes
from the jnp lowering's HLO, which materializes the (n, k) distance matrix
and is not comparable to either kernel model; lowering with
``--backend fused`` on a TPU target replaces the model with measurement
(ROADMAP open item).
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks.common import record
from benchmarks.kernel_bench import lloyd_hbm_bytes

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh="16x16"):
    paths = set(DRYRUN.glob(f"*__{mesh}.json"))
    for backend in ("pallas", "fused"):        # kmeans_dryrun --backend ...
        paths |= set(DRYRUN.glob(f"*__{mesh}__{backend}.json"))
    return [json.loads(p.read_text()) for p in sorted(paths)]


def fused_projection(rec):
    """For a kmeans dry-run record, the analytic per-device memory time of
    one fused-kernel Lloyd iteration over the device's shard.  Returns
    (ratio, memory_s_fused) or None when the record is not a Lloyd-loop
    cell (S1 has no assign/update phase) or was already lowered with the
    fused backend."""
    if not rec["arch"].startswith("kmeans-") or "-s1" in rec["arch"]:
        return None
    if rec.get("backend", "jnp") == "fused":
        return None
    m = re.match(r"n(\d+)_d(\d+)_k(\d+)", rec.get("shape", ""))
    if not m:
        return None
    n, d, k = map(int, m.groups())
    n_dev = 1
    for s in rec.get("mesh", "1").split("x"):
        n_dev *= int(s)
    n_local = -(-n // n_dev)
    ratio = lloyd_hbm_bytes(n_local, d, k, fused=False) \
        / lloyd_hbm_bytes(n_local, d, k, fused=True)
    from repro.launch.dryrun import HBM_BW
    return ratio, lloyd_hbm_bytes(n_local, d, k, fused=True) / HBM_BW


def run(mesh="16x16"):
    rows = []
    for r in load(mesh):
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r.get("status"),
                         "reason": r.get("reason", r.get("error", ""))[:120]})
            continue
        rf = r.get("roofline_expanded", r["roofline"])
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        flops = r.get("flops_expanded", r.get("flops", 0))
        row = {
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "roofline_fraction": rf["compute_s"] / bound if bound else 0.0,
            "useful_flops_ratio":
                r.get("model_flops_per_device", 0) / flops if flops else 0,
            "hbm_args_gb": r.get("argument_size_in_bytes", 0) / 2**30,
            "hbm_temp_gb": r.get("temp_size_in_bytes", 0) / 2**30,
        }
        if "backend" in r:
            row["backend"] = r["backend"]
        proj = fused_projection(r)
        if proj is not None:
            row["fused_hbm_ratio"], row["memory_s_fused"] = proj
        rows.append(row)
    ok = [r for r in rows if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline_fraction"]) if ok else None
    record(f"roofline_{mesh}", rows,
           ("roofline", "0",
            f"cells={len(ok)}"
            + (f",worst={worst['arch']}/{worst['shape']}"
               f"@{worst['roofline_fraction']:.3f}" if worst else "")))
    return rows


if __name__ == "__main__":
    run()
