"""§Roofline: render the three-term roofline table from the dry-run JSONs.

For the kmeans Lloyd cells the table also carries kernel memory projections
at two granularities:

  * per-ITERATION — ``memory_s_fused`` is the analytic per-device HBM time
    of one fused-kernel iteration (``kernel_bench.lloyd_hbm_bytes(...,
    fused=True)`` over the device's shard) and ``fused_hbm_ratio`` how much
    less traffic that is than the two-kernel path (~2x at d=64);
  * per-SOLVE — ``memory_s_resident_solve`` is the VMEM-resident engine's
    whole-solve HBM time (``kernel_bench.lloyd_solve_hbm_bytes``: the points
    cross HBM once per solve) and ``resident_solve_hbm_ratio`` its advantage
    over a fused per-step solve at ``NOMINAL_ITERS`` iterations — ~iters x
    for VMEM-feasible shards, 1x (fallback) otherwise.

All projection columns are analytic — the measured ``memory_s`` comes from
the jnp lowering's HLO, which materializes the (n, k) distance matrix and is
not comparable to any kernel model; lowering with ``--backend fused`` /
``--backend resident`` on a TPU target replaces the models with measurement
(ROADMAP open item).
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks.common import record
from benchmarks.kernel_bench import (NOMINAL_ITERS, lloyd_hbm_bytes,
                                     lloyd_solve_hbm_bytes)
from repro.kernels.resident import resident_feasible

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh="16x16"):
    paths = set(DRYRUN.glob(f"*__{mesh}.json"))
    for backend in ("pallas", "fused", "resident"):  # kmeans_dryrun --backend
        paths |= set(DRYRUN.glob(f"*__{mesh}__{backend}.json"))
    return [json.loads(p.read_text()) for p in sorted(paths)]


def _local_shape(rec):
    """Per-device (n_local, d, k) of a kmeans Lloyd-loop dry-run record, or
    None for non-Lloyd cells (S1 has no assign/update phase)."""
    if not rec["arch"].startswith("kmeans-") or "-s1" in rec["arch"]:
        return None
    m = re.match(r"n(\d+)_d(\d+)_k(\d+)", rec.get("shape", ""))
    if not m:
        return None
    n, d, k = map(int, m.groups())
    n_dev = 1
    for s in rec.get("mesh", "1").split("x"):
        n_dev *= int(s)
    return -(-n // n_dev), d, k


def fused_projection(rec):
    """For a kmeans dry-run record, the analytic per-device memory time of
    one fused-kernel Lloyd iteration over the device's shard.  Returns
    (ratio, memory_s_fused) or None when the record is not a Lloyd-loop
    cell or was already lowered with the fused backend."""
    if rec.get("backend", "jnp") == "fused":
        return None
    shape = _local_shape(rec)
    if shape is None:
        return None
    n_local, d, k = shape
    ratio = lloyd_hbm_bytes(n_local, d, k, fused=False) \
        / lloyd_hbm_bytes(n_local, d, k, fused=True)
    from repro.launch.dryrun import HBM_BW
    return ratio, lloyd_hbm_bytes(n_local, d, k, fused=True) / HBM_BW


def resident_projection(rec):
    """Per-SOLVE memory projection: the resident engine's whole-solve HBM
    time over the device's shard, and its advantage over a fused per-step
    solve at NOMINAL_ITERS iterations.  Infeasible (n, d, k) fall back to
    the fused per-step engine, so their ratio is pinned at 1.0."""
    if rec.get("backend", "jnp") == "resident":
        return None                            # already measured, not a projection
    shape = _local_shape(rec)
    if shape is None:
        return None
    n_local, d, k = shape
    fused_solve = lloyd_solve_hbm_bytes(n_local, d, k, NOMINAL_ITERS, "fused")
    if resident_feasible(n_local, d, k):
        res_solve = lloyd_solve_hbm_bytes(n_local, d, k, NOMINAL_ITERS,
                                          "resident")
    else:
        res_solve = fused_solve                # feasibility-guard fallback
    from repro.launch.dryrun import HBM_BW
    return fused_solve / res_solve, res_solve / HBM_BW


def run(mesh="16x16"):
    rows = []
    for r in load(mesh):
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r.get("status"),
                         "reason": r.get("reason", r.get("error", ""))[:120]})
            continue
        rf = r.get("roofline_expanded", r["roofline"])
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        flops = r.get("flops_expanded", r.get("flops", 0))
        row = {
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "roofline_fraction": rf["compute_s"] / bound if bound else 0.0,
            "useful_flops_ratio":
                r.get("model_flops_per_device", 0) / flops if flops else 0,
            "hbm_args_gb": r.get("argument_size_in_bytes", 0) / 2**30,
            "hbm_temp_gb": r.get("temp_size_in_bytes", 0) / 2**30,
        }
        if "backend" in r:
            row["backend"] = r["backend"]
        proj = fused_projection(r)
        if proj is not None:
            row["fused_hbm_ratio"], row["memory_s_fused"] = proj
        proj = resident_projection(r)
        if proj is not None:
            (row["resident_solve_hbm_ratio"],
             row["memory_s_resident_solve"]) = proj
        rows.append(row)
    ok = [r for r in rows if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline_fraction"]) if ok else None
    record(f"roofline_{mesh}", rows,
           ("roofline", "0",
            f"cells={len(ok)}"
            + (f",worst={worst['arch']}/{worst['shape']}"
               f"@{worst['roofline_fraction']:.3f}" if worst else "")))
    return rows


if __name__ == "__main__":
    run()
