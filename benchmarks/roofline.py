"""§Roofline: render the three-term roofline table from the dry-run JSONs."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import record

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh="16x16"):
    recs = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def run(mesh="16x16"):
    rows = []
    for r in load(mesh):
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r.get("status"),
                         "reason": r.get("reason", r.get("error", ""))[:120]})
            continue
        rf = r.get("roofline_expanded", r["roofline"])
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        flops = r.get("flops_expanded", r.get("flops", 0))
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "roofline_fraction": rf["compute_s"] / bound if bound else 0.0,
            "useful_flops_ratio":
                r.get("model_flops_per_device", 0) / flops if flops else 0,
            "hbm_args_gb": r.get("argument_size_in_bytes", 0) / 2**30,
            "hbm_temp_gb": r.get("temp_size_in_bytes", 0) / 2**30,
        })
    ok = [r for r in rows if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline_fraction"]) if ok else None
    record(f"roofline_{mesh}", rows,
           ("roofline", "0",
            f"cells={len(ok)}"
            + (f",worst={worst['arch']}/{worst['shape']}"
               f"@{worst['roofline_fraction']:.3f}" if worst else "")))
    return rows


if __name__ == "__main__":
    run()
