"""Paper Fig 8: partitioning x merging variant comparison.

Variants: 1) kd+random-label  2) kd+axis-label  3) global random partition,
merges: a) hierarchical  b) min-ASSE.  Paper ranking (worst -> best):
2+a < 3+b < 1+b < 2+b, vs single-machine k-means as the floor."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import record
from repro.core import IPKMeansConfig, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_3000

COMBOS = {
    "2+a": ("kd_axis", "hierarchical"),
    "3+b": ("random", "min_asse"),
    "1+b": ("kd_random", "min_asse"),
    "2+b": ("kd_axis", "min_asse"),
}
REDUCERS = (6, 11, 23, 46, 93)


def run():
    pts, _ = paper_dataset_3000(0)
    inits = initial_centroid_groups(pts, 5, groups=3)
    base = float(np.mean([float(pkmeans(pts, i).sse) for i in inits]))
    rows = []
    for name, (part, merge) in COMBOS.items():
        for m in REDUCERS:
            sses = []
            for s, init in enumerate(inits):
                cfg = IPKMeansConfig(num_clusters=5, num_subsets=m,
                                     partition=part, merge=merge)
                sses.append(float(ipkmeans(pts, init, jax.random.key(s),
                                           cfg).sse))
            rows.append({"combo": name, "reducers": m,
                         "mean_sse": float(np.mean(sses)),
                         "vs_single_machine_pct":
                             100 * (float(np.mean(sses)) / base - 1)})
    # paper's headline: 2+b is the best combo on average
    avg = {c: float(np.mean([r["mean_sse"] for r in rows
                             if r["combo"] == c])) for c in COMBOS}
    best = min(avg, key=avg.get)
    record("fig8_variants", rows, ("fig8_variants", "0", f"best={best}"))
    return rows


if __name__ == "__main__":
    run()
