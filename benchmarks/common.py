"""Shared benchmark utilities: timing, result recording, CSV emission."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def record(name: str, rows: list[dict], csv_line: tuple | None = None):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(rows, indent=2))
    if csv_line:
        print(",".join(str(x) for x in csv_line), flush=True)
    return rows
