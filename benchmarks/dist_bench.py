"""Pod-scaling bench for the multi-pod IPKMeans S2 (table3-style).

The single-mesh story (table2/table3) holds the subset axis fixed and
scales reducers; this table holds the problem fixed and scales PODS: the
same solve on (1x8), (2x4), (4x2) pods x devices meshes, comparing the
cross-pod reduction modes:

  * ``exact``  — f32 psum of per-cluster (sums, counts) every iteration;
  * ``int8ef`` — int8 error-feedback compression (per-row scales, residual
    carried across iterations) via ``distributed/compress.ef_allreduce``.

Columns per row: per-pod reduction payload bytes per Lloyd iteration
(measured with ``compress.payload_bytes`` on the actual wire trees),
rounds-to-converge (max subset Lloyd iterations), final SSE and its
relative delta vs the exact reduction on the same mesh.  The headline the
snapshot guard (``BENCH_dist.json`` in run.py) enforces: int8ef payload
<= 1/3 of exact — the paper's 2/3-lower-I/O claim restated at pod scale —
with SSE within 1e-3 relative.

Needs 8 devices, so the measurement runs in a subprocess with XLA
host-device virtualization (the harness process must keep seeing 1
device).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import record

REPO_ROOT = Path(__file__).resolve().parents[1]
_MARK = "DIST_BENCH_JSON:"

# the pod-scaling problem: d=32 puts the int8ef payload ratio at
# (k*d + 5k + 4) / (4k*(d+1)) = 300/1056 ~ 0.284, under the 1/3 gate
N, D, K, M = 4096, 32, 8, 16
MESHES = ((1, 8), (2, 4), (4, 2))

# the S1-sharding problem: big enough that the O(R*256) histogram DCN model
# undercuts the dataset by >10x (N=65536, leaf=4096 -> depth 4, R=16:
# ~280 KB of summaries vs 8.4 MB of points), small enough for a CPU bench
N_S1, LEAF_S1 = 1 << 16, 4096


def _worker() -> list[dict]:
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.ipkmeans import IPKMeansConfig, ipkmeans_distributed
    from repro.core.kmeans import KMeansParams
    from repro.data.synthetic import gaussian_mixture
    from repro.distributed import compress
    from repro.distributed.sharding import (KMEANS_DATA_AXIS,
                                            KMEANS_POD_AXIS, kmeans_pod_mesh)

    pts, _, _ = gaussian_mixture(jax.random.PRNGKey(0), N, K, d=D,
                                 spread=10.0, sigma=0.6)
    init = pts[jax.random.choice(jax.random.PRNGKey(1), N, (K,),
                                 replace=False)]
    cfg = IPKMeansConfig(num_clusters=K, num_subsets=M,
                         kmeans=KMeansParams(max_iters=300, tol=1e-6))

    # per-pod wire payload per Lloyd iteration, measured on the actual
    # trees: the f32 stats vs what compress_tree puts on the wire
    stats = {"sums": jnp.zeros((M, K, D), jnp.float32),
             "counts": jnp.zeros((M, K), jnp.float32)}
    exact_payload = compress.payload_bytes(stats)
    qtree, _ = compress.compress_tree(stats, compress.init_ef(stats),
                                      axes={"sums": -1, "counts": -1})
    int8_payload = compress.payload_bytes(qtree)

    rows = []
    for pods, dpp in MESHES:
        mesh = kmeans_pod_mesh(pods, dpp)
        pod_axis = KMEANS_POD_AXIS if pods > 1 else None
        sse_exact = None
        for mode in ("exact",) if pods == 1 else ("exact", "int8ef"):
            t0 = time.perf_counter()
            res = ipkmeans_distributed(
                pts, init, jax.random.PRNGKey(2), cfg.with_reduce(mode),
                mesh, axis_names=(KMEANS_DATA_AXIS,), pod_axis=pod_axis)
            jax.block_until_ready(res.centroids)
            wall = time.perf_counter() - t0
            sse = float(res.sse)
            if mode == "exact":
                sse_exact = sse
            payload = (0 if pod_axis is None
                       else exact_payload if mode == "exact"
                       else int8_payload)
            rows.append({
                "mode": "pod-scaling",
                "pods": pods, "devices_per_pod": dpp, "reduce": mode,
                "n": N, "d": D, "k": K, "subsets": M,
                "sse": sse,
                "sse_rel_delta_vs_exact": abs(sse - sse_exact) / sse_exact,
                "rounds": int(max(res.subset_iters.tolist())),
                "payload_bytes_per_pod_per_iter": payload,
                "payload_ratio_vs_exact": payload / exact_payload,
                "wall_sec": wall,
            })

    # ---- S1-sharding rows: the kd partition itself across pods ----
    # sharded histogram build + labeling on the 2x4 pod mesh vs the
    # replicated sort build, with the DCN byte model for each; the sharded
    # path's region/subset ids must be bit-identical to the single-device
    # histogram reference (the snapshot guard enforces it).
    import numpy as np

    from repro.core import io_model, kdtree

    pts1, _, _ = gaussian_mixture(jax.random.PRNGKey(5), N_S1, K, d=D,
                                  spread=10.0, sigma=0.6)
    depth = kdtree.required_depth(N_S1, LEAF_S1)
    key = jax.random.PRNGKey(6)
    mesh = kmeans_pod_mesh(2, 4)
    axes = (KMEANS_POD_AXIS, KMEANS_DATA_AXIS)
    points_bytes = N_S1 * D * 4

    def timed_partition(**kw):
        part = None
        for _ in range(2):                      # 2nd call: compile-free
            t0 = time.perf_counter()
            part = kdtree.partition_dataset(pts1, key, M,
                                            leaf_capacity=LEAF_S1, **kw)
            jax.block_until_ready(part.subset_ids)
            wall = time.perf_counter() - t0
        return part, wall

    ref, _ = timed_partition(builder="histogram", labeler="histogram")
    shard, wall_shard = timed_partition(builder="histogram",
                                        labeler="histogram",
                                        mesh=mesh, axis_names=axes)
    _, wall_sort = timed_partition(builder="sort", labeler="sort")
    hist_model = io_model.s1_histogram_dcn_bytes(depth, 2)
    sort_model = io_model.s1_sort_dcn_bytes(N_S1, D, depth)
    rows.append({
        "mode": "s1-sharding", "variant": "sharded-histogram",
        "pods": 2, "devices_per_pod": 4,
        "n": N_S1, "d": D, "subsets": M, "kd_depth": depth,
        "region_ids_exact": bool(np.array_equal(np.asarray(shard.region_ids),
                                                np.asarray(ref.region_ids))),
        "subset_ids_exact": bool(np.array_equal(np.asarray(shard.subset_ids),
                                                np.asarray(ref.subset_ids))),
        "s1_dcn_payload_bytes": hist_model,
        "points_bytes": points_bytes,
        "payload_ratio_vs_points": hist_model / points_bytes,
        "wall_sec": wall_shard,
    })
    rows.append({
        "mode": "s1-sharding", "variant": "replicated-sort",
        "pods": 2, "devices_per_pod": 4,
        "n": N_S1, "d": D, "subsets": M, "kd_depth": depth,
        "s1_dcn_payload_bytes": sort_model,
        "points_bytes": points_bytes,
        "payload_ratio_vs_points": sort_model / points_bytes,
        "wall_sec": wall_sort,
    })
    return rows


def run() -> list[dict]:
    env = {"PYTHONPATH": f"{REPO_ROOT}/src:{REPO_ROOT}",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",     # skip the TPU-probe minutes on
                                       # machines that carry libtpu
           "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
           "HOME": os.environ.get("HOME", "/root")}
    code = ("import json\n"
            "from benchmarks import dist_bench\n"
            f"print({_MARK!r} + json.dumps(dist_bench._worker()))\n")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"dist_bench worker failed:\n{res.stderr[-3000:]}")
    line = next(l for l in res.stdout.splitlines() if l.startswith(_MARK))
    rows = json.loads(line[len(_MARK):])
    q = [r for r in rows if r.get("reduce") == "int8ef"]
    ratio = max(r["payload_ratio_vs_exact"] for r in q)
    delta = max(r["sse_rel_delta_vs_exact"] for r in q)
    s1 = next(r for r in rows if r.get("variant") == "sharded-histogram")
    record("dist_bench", rows,
           ("dist_bench", f"{rows[0]['wall_sec']*1e6:.0f}",
            f"int8ef_payload_ratio={ratio:.3f} max_sse_rel_delta={delta:.1e} "
            f"s1_dcn_ratio={s1['payload_ratio_vs_points']:.3f} "
            f"s1_ids_exact={s1['region_ids_exact'] and s1['subset_ids_exact']}"))
    return rows


if __name__ == "__main__":
    run()
