"""Paper Fig 6: execution time IPKMeans vs PKMeans, same data/seeds.

Two views: (a) measured wall time of the JAX solvers on this host;
(b) modeled Hadoop seconds (job startup + calibrated shuffle + disk), the
apples-to-apples reproduction of the paper's environment.  Claim: up to 2/3
less time; PKMeans can win when it converges in very few iterations
(paper experiments 2-3)."""
from __future__ import annotations

import jax

from benchmarks.common import record, timeit
from repro.core import IPKMeansConfig, io_model, ipkmeans, pkmeans
from repro.data import initial_centroid_groups, paper_dataset_3000


def run():
    pts, _ = paper_dataset_3000(0)
    inits = initial_centroid_groups(pts, 5, groups=5)
    cfg = IPKMeansConfig(num_clusters=5, num_subsets=6)
    model = io_model.HadoopCostModel()
    rows = []
    for i, init in enumerate(inits):
        ref = pkmeans(pts, init)
        res = ipkmeans(pts, init, jax.random.key(0), cfg)
        t_pk = timeit(lambda init=init: pkmeans(pts, init))
        t_ipk = timeit(lambda init=init: ipkmeans(pts, init,
                                                  jax.random.key(0), cfg))
        h_pk = model.pkmeans_sec(3000, 2, 5, int(ref.iters))
        h_ipk = model.ipkmeans_sec(3000, 2, 5, 6, int(res.kd_depth))
        rows.append({
            "experiment": i + 1,
            "jax_sec_pkmeans": t_pk, "jax_sec_ipkmeans": t_ipk,
            "hadoop_model_sec_pkmeans": h_pk,
            "hadoop_model_sec_ipkmeans": h_ipk,
            "hadoop_time_reduction": 1 - h_ipk / h_pk,
        })
    best = max(r["hadoop_time_reduction"] for r in rows)
    t = rows[0]["jax_sec_ipkmeans"]
    record("fig6_time", rows,
           ("fig6_time", f"{t*1e6:.0f}", f"best_time_reduction={best:.3f}"))
    return rows


if __name__ == "__main__":
    run()
